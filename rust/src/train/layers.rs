//! TT-format linear layer with a hand-derived backward pass through the
//! bidirectional (BTT) contraction — the paper's BP stage for one layer.
//!
//! Forward (row-major, K = sequence length):
//!
//! ```text
//! Z3 = fold(G_1 .. G_d)          (M, r_d)   left merge, K-independent
//! Z1 = fold(G_2d .. G_{d+1})     (r_d, N)   right merge, K-independent
//! Z2 = X Z1^T                    (K, r_d)
//! Y  = Z2 Z3^T + b               (K, M)
//! ```
//!
//! Backward reuses the cached chain states (the paper's "stored
//! intermediates", Eq. 21) and costs exactly `2x` the forward
//! multiplies — [`crate::costmodel::LinearShape::btt_bwd_muls`] is the
//! analytic form, asserted against the executed
//! [`ContractionStats`] in the tests.

use crate::optim::{Hyper, ModelOptim};
use crate::tensor::precision::PackedVec;
use crate::tensor::{
    ops, ContractionStats, PackedTTMatrix, PackedTensor, Precision, Tensor, TTMatrix,
};
use crate::trace;
use anyhow::{anyhow, Result};
use std::borrow::Cow;

/// A trainable TT-format linear layer (cores + dense bias).
///
/// The cores and bias live **at rest** in a [`PackedTTMatrix`] /
/// [`PackedVec`]: genuinely `u16`-packed buffers under the half
/// precisions (half the measured bytes), plain f32 otherwise.  Reads
/// go through [`TTLinear::tt`] / [`TTLinear::bias`], which widen on
/// load (zero-copy borrows on the f32 path); writes go through
/// [`TTLinear::update_tt`] / [`TTLinear::update_bias`], which repack
/// on store.  Because the PU stage rounds every updated parameter to
/// the storage precision ([`ModelOptim::step`]), the at-rest values
/// are always exactly representable and the widen/repack round trip
/// is bitwise lossless — packed storage computes the same bits as the
/// rounded-f32 representation it replaces.
#[derive(Debug, Clone)]
pub struct TTLinear {
    store: PackedTTMatrix,
    bias: PackedVec,
}

/// Per-layer gradient-checkpointing mode: what the forward pass retains
/// of the Eq. 21 intermediates for the BP stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CheckpointMode {
    /// Store the full merge chains and Z2 (the paper's schedule; the
    /// cache holds exactly the Eq. 21 elements).
    CacheAll,
    /// Store only the layer input; the BP stage re-runs the forward
    /// contraction (same deterministic fold order, same round-on-store
    /// precision) to rebuild the chains and Z2 before computing grads.
    /// Trades the Eq. 21 bytes for
    /// [`crate::costmodel::LinearShape::btt_recompute_muls`] extra
    /// multiplies.  Valid only while the layer's weights are unchanged
    /// between its forward and its backward — the training loop's
    /// backward-before-update order per layer guarantees this.
    Recompute,
}

/// The dropped-under-`Recompute` part of a [`TTLinearCache`]: the merge
/// chains and Z2, stored at the layer's storage [`Precision`].
struct TTLinearStates {
    /// Left-merge chain states; last is Z3 (M, r_d).
    left_chain: Vec<PackedTensor>,
    /// Right-merge chain states; last is Z1 (r_d, N).
    right_chain: Vec<PackedTensor>,
    /// Z2 = X Z1^T (K, r_d).
    z2: PackedTensor,
}

/// Forward activations cached for the BP stage, stored at the layer's
/// storage [`Precision`] — genuinely `u16`-packed for the half formats
/// ([`PackedTensor`]), so the Eq. 21 cache really occupies half the
/// bytes.  The backward pass widens on load and accumulates in f32.
/// Under [`CheckpointMode::Recompute`] only the layer input survives
/// the forward pass; the backward rebuilds the chain states through
/// the same fold order before unrolling them.
pub struct TTLinearCache {
    /// Layer input (K, N).
    pub x: PackedTensor,
    /// Merge chains + Z2 under [`CheckpointMode::CacheAll`]; `None`
    /// under [`CheckpointMode::Recompute`].  The storage precision of
    /// every retained (and recomputed) state is `x`'s precision.
    states: Option<TTLinearStates>,
}

impl TTLinearCache {
    /// Elements this cache stores beyond weights and the layer input —
    /// equals Eq. 21 (`LinearShape::btt_training_cache_elems`) under
    /// `CacheAll` and **0** under `Recompute` (the chains and Z2 are
    /// rebuilt transiently by the BP stage).  The first chain state on
    /// each side is a reshaped core (weight memory, not an activation)
    /// and is excluded.
    pub fn stored_elems(&self) -> u64 {
        match &self.states {
            None => 0,
            Some(s) => {
                let chain: usize = s
                    .left_chain
                    .iter()
                    .skip(1)
                    .chain(s.right_chain.iter().skip(1))
                    .map(PackedTensor::numel)
                    .sum();
                (chain + s.z2.numel()) as u64
            }
        }
    }

    /// Bytes the Eq. 21 cache occupies at rest: `stored_elems` at the
    /// storage width — exactly half the f32 figure for bf16/f16, ~1/4
    /// (codes + per-block scales) for int8, and 0 under `Recompute`.
    pub fn stored_bytes(&self) -> u64 {
        self.x.precision().storage_bytes(self.stored_elems())
    }

    /// The checkpointing mode this cache was built under.
    pub fn mode(&self) -> CheckpointMode {
        if self.states.is_some() {
            CheckpointMode::CacheAll
        } else {
            CheckpointMode::Recompute
        }
    }
}

/// Fold a state-rebuild scratch into `stats`.  The forward (`stored`)
/// keeps the full Eq. 21 stored-element accounting; the BP-stage
/// `Recompute` rebuild charges multiplies and steps only — the rebuilt
/// states are transient (dropped as soon as the layer's gradients are
/// out), so they never join the stored-element count.
fn record_rebuild(stats: &mut ContractionStats, scratch: ContractionStats, stored: bool) {
    stats.muls += scratch.muls;
    stats.steps += scratch.steps;
    if stored {
        stats.stored_intermediate_elems += scratch.stored_intermediate_elems;
        stats.peak_intermediate_elems =
            stats.peak_intermediate_elems.max(scratch.peak_intermediate_elems);
    }
}

/// Compute the merge chains and `Z2 = X Z1^T` of one BTT layer from
/// its cores and the (already rounded) input — the **single
/// definition of the fold order** that both [`TTLinear::forward_ckpt`]
/// and the `Recompute` arm of [`TTLinear::backward`] go through, so
/// the recomputed states are bitwise the cached ones by construction.
/// `stored` selects Eq. 21 stored-element accounting (forward) vs the
/// transient BP rebuild (multiplies only — the cost model's
/// `btt_recompute_muls`).
fn build_btt_states(
    tt: &TTMatrix,
    xq: &Tensor,
    prec: Precision,
    stored: bool,
    stats: &mut ContractionStats,
) -> Result<(Vec<Tensor>, Vec<Tensor>, Tensor)> {
    let (k_dim, n) = (xq.shape[0], tt.n());
    let r_d = tt.ranks[tt.d()];
    let mut scratch = ContractionStats::default();
    let sp = trace::span("ttlinear", "merge_left");
    let left = tt.merge_left_chain_prec(prec)?;
    drop(sp);
    let sp = trace::span("ttlinear", "merge_right");
    let right = tt.merge_right_chain_prec(prec)?;
    drop(sp);
    tt.record_merge_stats(&mut scratch);
    let z1 = right.last().expect("d >= 1");
    let sp = trace::span("ttlinear", "apply");
    let z2 = prec.round_tensor_owned(xq.matmul(&z1.t()?)?); // (K, r_d)
    drop(sp);
    scratch.record_step((k_dim * n * r_d) as u64, (k_dim * r_d) as u64, stored);
    record_rebuild(stats, scratch, stored);
    Ok((left, right, z2))
}

/// Parameter gradients of one layer.
pub struct TTLinearGrads {
    /// One gradient tensor per TT core (same shapes as the cores).
    pub cores: Vec<Tensor>,
    pub bias: Vec<f32>,
}

impl TTLinear {
    /// Build from f32 cores and bias; the layer stores them at
    /// [`Precision::F32`] until [`TTLinear::set_precision`] repacks.
    pub fn new(tt: TTMatrix, bias: Vec<f32>) -> Result<TTLinear> {
        if bias.len() != tt.m() {
            return Err(anyhow!("bias len {} != M {}", bias.len(), tt.m()));
        }
        Ok(TTLinear {
            store: PackedTTMatrix::pack_owned(tt, Precision::F32),
            bias: PackedVec::from_f32(Precision::F32, &bias),
        })
    }

    /// Random layer with zero bias (TT cores scaled for `target_std` of
    /// the reconstructed dense matrix).
    pub fn randn(
        m_modes: &[usize],
        n_modes: &[usize],
        rank: usize,
        target_std: f32,
        rng: &mut crate::util::rng::SplitMix64,
    ) -> TTLinear {
        let tt = TTMatrix::randn(m_modes, n_modes, rank, target_std, rng);
        let bias = vec![0.0; tt.m()];
        TTLinear::new(tt, bias).expect("bias sized to M")
    }

    /// Widen-on-load view of the TT cores: a zero-copy borrow on the
    /// f32 path, an exact widening for the packed half formats.
    pub fn tt(&self) -> Cow<'_, TTMatrix> {
        self.store.view()
    }

    /// Widen-on-load view of the bias row.
    pub fn bias(&self) -> Cow<'_, [f32]> {
        self.bias.view()
    }

    /// Mutate the cores through a widen → edit → repack-on-store round
    /// trip (in place on the f32 path).
    pub fn update_tt(&mut self, f: impl FnOnce(&mut TTMatrix)) {
        self.store.update(f);
    }

    /// Mutate the bias through the same round trip.
    pub fn update_bias(&mut self, f: impl FnOnce(&mut [f32])) {
        self.bias.update_in_place(f);
    }

    /// Storage precision of the at-rest cores and bias.
    pub fn precision(&self) -> Precision {
        self.store.precision()
    }

    /// Re-store cores and bias at `prec` (bitwise lossless for values
    /// already representable there — i.e. anything the PU stage wrote).
    pub fn set_precision(&mut self, prec: Precision) {
        self.store.set_precision(prec);
        self.bias.set_precision(prec);
    }

    /// Trainable parameter count (cores + bias).
    pub fn param_count(&self) -> usize {
        self.store.param_count() + self.bias.len()
    }

    /// **Measured** parameter bytes at rest: the sum of the actual
    /// core and bias buffer sizes at the stored precision — exactly
    /// half the f32 figure under bf16/f16.
    pub fn param_bytes(&self) -> u64 {
        self.store.bytes() + self.bias.bytes()
    }

    /// Forward pass `Y = X W^T + b` on row-major `x (K, N)` at full
    /// precision, caching the BTT intermediates for backward.
    /// Instrumented identically to [`TTMatrix::matmul_btt`] (the
    /// executed counts equal Eqs. 20/21).
    pub fn forward(
        &self,
        x: &Tensor,
        stats: &mut ContractionStats,
    ) -> Result<(Tensor, TTLinearCache)> {
        self.forward_prec(x, Precision::F32, stats)
    }

    /// [`TTLinear::forward`] under the mixed-precision storage path:
    /// every stored value — the cached input, each merge-chain state
    /// and Z2 — is rounded to `prec` on store (round-to-nearest-even)
    /// and the *rounded* value feeds the next product, so the cache the
    /// BP stage reads is exactly what the forward computed through.
    /// All products accumulate in f32 (widen-on-load); the cache itself
    /// is packed to half width.  `Precision::F32` is bitwise the
    /// full-precision forward.
    pub fn forward_prec(
        &self,
        x: &Tensor,
        prec: Precision,
        stats: &mut ContractionStats,
    ) -> Result<(Tensor, TTLinearCache)> {
        self.forward_ckpt(x, prec, CheckpointMode::CacheAll, stats)
    }

    /// [`TTLinear::forward_prec`] under a gradient-checkpointing mode.
    /// `Recompute` runs the identical contraction (same multiplies,
    /// same output bits) but retains only the rounded layer input; the
    /// chains and Z2 are dropped and rebuilt by [`TTLinear::backward`].
    /// `stats` records the *computed* Eq. 21 intermediates either way —
    /// what is actually retained is the cache's
    /// [`TTLinearCache::stored_bytes`].
    pub fn forward_ckpt(
        &self,
        x: &Tensor,
        prec: Precision,
        mode: CheckpointMode,
        stats: &mut ContractionStats,
    ) -> Result<(Tensor, TTLinearCache)> {
        let (y_raw, cache) = self.forward_ckpt_raw(x, prec, mode, stats)?;
        Ok((ops::add_row(&y_raw, &self.bias()), cache))
    }

    /// [`TTLinear::forward_ckpt`] **without the bias row-add**: returns
    /// the raw TT-apply output `X W^T` so a fused elementwise lane
    /// (bias + residual + LayerNorm, or bias + GELU — see
    /// `train::blocks`) can consume it element-by-element without the
    /// intermediate `Y = X W^T + b` ever round-tripping through memory.
    /// The cache is identical to [`TTLinear::forward_ckpt`]'s.
    pub fn forward_ckpt_raw(
        &self,
        x: &Tensor,
        prec: Precision,
        mode: CheckpointMode,
        stats: &mut ContractionStats,
    ) -> Result<(Tensor, TTLinearCache)> {
        let tt = self.tt();
        let d = tt.d();
        let (m, n) = (tt.m(), tt.n());
        if x.ndim() != 2 || x.shape[1] != n {
            return Err(anyhow!("x must be (K, {n}), got {:?}", x.shape));
        }
        let k_dim = x.shape[0];
        let r_d = tt.ranks[d];

        let xq = prec.round_tensor(x);
        // Chains + Z2 through the shared builder (the same fold order
        // the `Recompute` backward re-runs; merge costs go through the
        // same accounting helper as matmul_btt).
        let (left_chain, right_chain, z2) = build_btt_states(&tt, &xq, prec, true, stats)?;
        let z3 = left_chain.last().expect("d >= 1");
        let sp = trace::span("ttlinear", "apply");
        let y = z2.matmul(&z3.t()?)?; // (K, M)
        drop(sp);
        stats.record_step((k_dim * r_d * m) as u64, (k_dim * m) as u64, false);
        let pack = |t: Tensor| PackedTensor::pack_owned(t, prec);
        let states = match mode {
            CheckpointMode::Recompute => None,
            CheckpointMode::CacheAll => Some(TTLinearStates {
                left_chain: left_chain.into_iter().map(pack).collect(),
                right_chain: right_chain.into_iter().map(pack).collect(),
                z2: pack(z2),
            }),
        };
        Ok((y, TTLinearCache { x: pack(xq), states }))
    }

    /// Backward pass: given `dY (K, M)` and the forward cache, return
    /// `dX (K, N)` and the parameter gradients.  Executed multiplies are
    /// recorded into `stats` and equal `btt_bwd_muls` (2x Eq. 20).
    pub fn backward(
        &self,
        dy: &Tensor,
        cache: &TTLinearCache,
        stats: &mut ContractionStats,
    ) -> Result<(Tensor, TTLinearGrads)> {
        let tt = self.tt();
        let d = tt.d();
        let (m, n) = (tt.m(), tt.n());
        let r_d = tt.ranks[d];
        if dy.ndim() != 2 || dy.shape[1] != m || dy.shape[0] != cache.x.shape()[0] {
            return Err(anyhow!("dy must be (K, {m}), got {:?}", dy.shape));
        }
        let k_dim = dy.shape[0];

        // Bias gradient: column sums of dY (additions only).
        let mut dbias = vec![0.0f32; m];
        for row in dy.data.chunks(m) {
            for (b, &v) in dbias.iter_mut().zip(row) {
                *b += v;
            }
        }

        // Widen-on-load: view the cache as f32 once — zero-copy
        // borrows on the f32 path, exact widenings for the packed half
        // formats.  Every product below accumulates in f32.  Under
        // `Recompute` the chains and Z2 are rebuilt here from the
        // stored input and the cores (unchanged since the forward, by
        // the backward-before-update contract), through the exact same
        // fold order and round-on-store precision as the forward — so
        // the recomputed states are bitwise the cached ones at every
        // precision.  The rebuild is charged as transient multiplies
        // (`btt_recompute_muls`), never as stored intermediates.
        let x = cache.x.view();
        let (left_chain, right_chain, z2): (
            Vec<Cow<'_, Tensor>>,
            Vec<Cow<'_, Tensor>>,
            Cow<'_, Tensor>,
        ) = match &cache.states {
            Some(s) => (
                s.left_chain.iter().map(PackedTensor::view).collect(),
                s.right_chain.iter().map(PackedTensor::view).collect(),
                s.z2.view(),
            ),
            None => {
                let prec = cache.x.precision();
                let (left, right, z2) = build_btt_states(&tt, x.as_ref(), prec, false, stats)?;
                (
                    left.into_iter().map(Cow::Owned).collect(),
                    right.into_iter().map(Cow::Owned).collect(),
                    Cow::Owned(z2),
                )
            }
        };
        let z3 = left_chain.last().expect("d >= 1").as_ref();
        let z1 = right_chain.last().expect("d >= 1").as_ref();
        // The four K-wide products (2 K r_d (M + N) multiplies).
        let dz3 = dy.t()?.matmul(z2.as_ref())?; // (M, r_d)
        stats.record_step((m * k_dim * r_d) as u64, (m * r_d) as u64, false);
        let dz2 = dy.matmul(z3)?; // (K, r_d)
        stats.record_step((k_dim * m * r_d) as u64, (k_dim * r_d) as u64, false);
        let dz1 = dz2.t()?.matmul(x.as_ref())?; // (r_d, N)
        stats.record_step((r_d * k_dim * n) as u64, (r_d * n) as u64, false);
        let dx = dz2.matmul(z1)?; // (K, N)
        stats.record_step((k_dim * r_d * n) as u64, (k_dim * n) as u64, false);

        let mut core_grads = unroll_left_chain(&tt, &left_chain, dz3, stats)?;
        core_grads.extend(unroll_right_chain(&tt, &right_chain, dz1, stats)?);

        Ok((dx, TTLinearGrads { cores: core_grads, bias: dbias }))
    }

    /// The paper's PU stage for this layer: dispatch every core (and the
    /// bias) through the pluggable optimizer, in place, as gradients
    /// become available.  `prefix` is the layer's checkpoint/manifest
    /// name (e.g. `layers.0.wq`), which keys the per-core optimizer
    /// state — state buffers mirror the compressed core shapes exactly.
    pub fn apply_update(
        &mut self,
        grads: &TTLinearGrads,
        opt: &mut ModelOptim,
        prefix: &str,
        hyper: &Hyper,
    ) {
        // The optimizer rounds every updated value to the storage
        // precision, so the repack-on-store below is bitwise lossless.
        self.store.update(|tt| {
            for (k, (core, g)) in tt.cores.iter_mut().zip(&grads.cores).enumerate() {
                opt.step(&format!("{prefix}.cores.{k}"), &mut core.data, &g.data, hyper);
            }
        });
        self.bias
            .update_in_place(|b| opt.step(&format!("{prefix}.bias"), b, &grads.bias, hyper));
    }
}

/// Unroll one left (output-side) merge chain: `dL_k -> (dG_k, dL_{k-1})`.
/// Returns the `d` output-mode core gradients (index `k` matches core
/// `k`).  Shared by [`TTLinear::backward`] and [`backward_qkv_fused`];
/// takes the chain as [`Cow`] views so the f32 path stays zero-copy.
fn unroll_left_chain(
    tt: &TTMatrix,
    chain: &[Cow<'_, Tensor>],
    dz3: Tensor,
    stats: &mut ContractionStats,
) -> Result<Vec<Tensor>> {
    let d = tt.d();
    let mut grads: Vec<Tensor> = (0..d).map(|k| Tensor::zeros(&tt.cores[k].shape)).collect();
    let mut d_state = dz3;
    for k in (1..d).rev() {
        let g = &tt.cores[k];
        let (rp, mk, rk) = (g.shape[0], g.shape[1], g.shape[2]);
        let prev = chain[k - 1].as_ref(); // (m_prev, rp)
        let m_prev = prev.shape[0];
        let dflat = d_state.reshape(&[m_prev, mk * rk])?;
        let dg = prev.t()?.matmul(&dflat)?; // (rp, mk*rk)
        stats.record_step((rp * m_prev * mk * rk) as u64, (rp * mk * rk) as u64, false);
        grads[k] = dg.reshape(&[rp, mk, rk])?;
        d_state = dflat.matmul(&g.reshape(&[rp, mk * rk])?.t()?)?; // (m_prev, rp)
        stats.record_step((m_prev * mk * rk * rp) as u64, (m_prev * rp) as u64, false);
    }
    grads[0] = d_state.reshape(&tt.cores[0].shape)?;
    Ok(grads)
}

/// Unroll one right (input-side) merge chain: `dR_j -> (dG_{2d-1-j},
/// dR_{j-1})`.  Returns the `d` input-mode core gradients (index `j`
/// matches core `d + j`).
fn unroll_right_chain(
    tt: &TTMatrix,
    chain: &[Cow<'_, Tensor>],
    dz1: Tensor,
    stats: &mut ContractionStats,
) -> Result<Vec<Tensor>> {
    let d = tt.d();
    let d2 = 2 * d;
    let mut grads: Vec<Tensor> = (d..d2).map(|c| Tensor::zeros(&tt.cores[c].shape)).collect();
    let mut d_state = dz1;
    for j in (1..d).rev() {
        let c = d2 - 1 - j;
        let g = &tt.cores[c];
        let (rp, nk, rk) = (g.shape[0], g.shape[1], g.shape[2]);
        let prev = chain[j - 1].as_ref(); // (rk, n_prev)
        let n_prev = prev.shape[1];
        let dflat = d_state.reshape(&[rp * nk, n_prev])?;
        let dg = dflat.matmul(&prev.t()?)?; // (rp*nk, rk)
        stats.record_step((rp * nk * n_prev * rk) as u64, (rp * nk * rk) as u64, false);
        grads[c - d] = dg.reshape(&[rp, nk, rk])?;
        d_state = g.reshape(&[rp * nk, rk])?.t()?.matmul(&dflat)?; // (rk, n_prev)
        stats.record_step((rk * rp * nk * n_prev) as u64, (rk * n_prev) as u64, false);
    }
    grads[d - 1] = d_state.reshape(&tt.cores[d2 - 1].shape)?;
    Ok(grads)
}

// ---------------------------------------------------------------------------
// Fused QKV: one shared input-side merge feeding three projections
//
// The paper's Fig. 9 reschedules the Q/K/V merge chains so shared
// contraction work is not triplicated.  Realized in compute: when the
// three projections share their input-side cores `G_{d+1}..G_{2d}`
// (tied at init and kept in lockstep by `apply_update_qkv_fused`), one
// right merge produces one Z1, one `Z2 = X Z1^T` feeds all three
// output-side applies, and the backward aggregates the input-side
// gradient through a single summed dZ2.  Forward multiplies drop from
// `3 (L + R + K r_d (M + N))` to `3L + R + K r_d (3M + N)`
// ([`crate::costmodel::LinearShape::btt_fwd_qkv_muls`]); the backward
// stays exactly 2x the fused forward.
// ---------------------------------------------------------------------------

/// True iff the three projections can run the fused QKV schedule:
/// identical mode/rank structure and **bitwise-equal input-side cores**
/// `G_{d+1}..G_{2d}`.  Checkpoints trained with independent projections
/// report `false` and fall back to three separate forwards.
pub fn qkv_input_cores_shared(wq: &TTLinear, wk: &TTLinear, wv: &TTLinear) -> bool {
    tt_input_cores_tied(&wq.tt(), &wk.tt(), &wv.tt())
}

/// Core of [`qkv_input_cores_shared`] on raw [`TTMatrix`] triples —
/// also the load-time tie check of [`crate::engine::NativeEngine`],
/// which sees the cores before they are merged away.
pub fn tt_input_cores_tied(q: &TTMatrix, k: &TTMatrix, v: &TTMatrix) -> bool {
    let d = q.d();
    [k, v].iter().all(|w| {
        w.m_modes == q.m_modes
            && w.n_modes == q.n_modes
            && w.ranks == q.ranks
            && (d..2 * d).all(|c| w.cores[c] == q.cores[c])
    })
}

/// The dropped-under-`Recompute` part of a [`QkvFusedCache`].
struct QkvFusedStates {
    /// Per-projection left-merge chains (q, k, v); last state is Z3.
    left_chains: [Vec<PackedTensor>; 3],
    /// Shared right-merge chain; last state is Z1 (r_d, N).
    right_chain: Vec<PackedTensor>,
    /// Shared Z2 = X Z1^T (K, r_d).
    z2: PackedTensor,
}

/// Forward activations of the fused QKV pass.  The layer input and the
/// shared right chain / Z2 are stored **once** (vs three copies across
/// separate [`TTLinearCache`]s), at the layer's storage [`Precision`]
/// (packed to half width for bf16/f16).  Under
/// [`CheckpointMode::Recompute`] only the layer input survives; the
/// backward rebuilds the shared right chain, Z2 and the three left
/// chains through the same fold order.
pub struct QkvFusedCache {
    /// Layer input (K, N), stored once for all three projections.
    pub x: PackedTensor,
    /// Chains + Z2 under [`CheckpointMode::CacheAll`]; `None` under
    /// [`CheckpointMode::Recompute`].  The storage precision of every
    /// retained (and recomputed) state is `x`'s precision.
    states: Option<QkvFusedStates>,
}

impl QkvFusedCache {
    /// Activation elements stored beyond weights and the layer input —
    /// equals [`crate::costmodel::LinearShape::btt_qkv_memory`] under
    /// `CacheAll` and **0** under `Recompute`.  The first chain state
    /// on each side is a reshaped core and excluded.
    pub fn stored_elems(&self) -> u64 {
        match &self.states {
            None => 0,
            Some(s) => {
                let chains: usize = s
                    .left_chains
                    .iter()
                    .flat_map(|c| c.iter().skip(1))
                    .chain(s.right_chain.iter().skip(1))
                    .map(PackedTensor::numel)
                    .sum();
                (chains + s.z2.numel()) as u64
            }
        }
    }

    /// Bytes at rest of the fused Eq. 21 cache (see
    /// [`TTLinearCache::stored_bytes`]).
    pub fn stored_bytes(&self) -> u64 {
        self.x.precision().storage_bytes(self.stored_elems())
    }

    /// The checkpointing mode this cache was built under.
    pub fn mode(&self) -> CheckpointMode {
        if self.states.is_some() {
            CheckpointMode::CacheAll
        } else {
            CheckpointMode::Recompute
        }
    }
}

/// Parameter gradients of the fused QKV pass.
pub struct QkvFusedGrads {
    /// Output-side core gradients per projection (q, k, v), `d` each.
    pub m_cores: [Vec<Tensor>; 3],
    /// Shared input-side core gradients (`d` tensors for cores
    /// `d..2d`), already summed over the three projections.
    pub n_cores: Vec<Tensor>,
    /// Bias gradients per projection.
    pub bias: [Vec<f32>; 3],
}

/// Compute the shared right chain, the shared `Z2 = X Z1^T` and the
/// three per-projection left chains of the fused QKV pass — the
/// **single definition of the fused fold order** that both
/// [`forward_qkv_fused_ckpt`] and the `Recompute` arm of
/// [`backward_qkv_fused`] go through.  The right merge and Z2 are
/// charged once, the left merges per projection; `stored` selects
/// Eq. 21 stored-element accounting (forward) vs the transient BP
/// rebuild (multiplies only — `btt_qkv_recompute_muls`).
fn build_qkv_states(
    qtt: &TTMatrix,
    ktt: &TTMatrix,
    vtt: &TTMatrix,
    xq: &Tensor,
    prec: Precision,
    stored: bool,
    stats: &mut ContractionStats,
) -> Result<([Vec<Tensor>; 3], Vec<Tensor>, Tensor)> {
    let d = qtt.d();
    let (k_dim, n) = (xq.shape[0], qtt.n());
    let r_d = qtt.ranks[d];
    let mut scratch = ContractionStats::default();
    let sp = trace::span("ttlinear", "merge_right");
    let right = qtt.merge_right_chain_prec(prec)?;
    drop(sp);
    qtt.record_merge_right_stats(&mut scratch);
    let z1 = right.last().expect("d >= 1");
    let sp = trace::span("ttlinear", "apply");
    let z2 = prec.round_tensor_owned(xq.matmul(&z1.t()?)?); // (K, r_d)
    drop(sp);
    scratch.record_step((k_dim * n * r_d) as u64, (k_dim * r_d) as u64, stored);
    let mut lefts = Vec::with_capacity(3);
    for tt in [qtt, ktt, vtt] {
        let _sp = trace::span("ttlinear", "merge_left");
        lefts.push(tt.merge_left_chain_prec(prec)?);
        tt.record_merge_left_stats(&mut scratch);
    }
    record_rebuild(stats, scratch, stored);
    Ok((lefts.try_into().expect("three projections"), right, z2))
}

/// Fused QKV forward on row-major `x (K, N)`: returns `[q, k, v]`
/// (each `(K, M)`, bias added) and the shared cache.  Requires
/// [`qkv_input_cores_shared`]; instrumentation charges the right merge
/// and Z2 once (`btt_fwd_qkv_muls`).
pub fn forward_qkv_fused(
    wq: &TTLinear,
    wk: &TTLinear,
    wv: &TTLinear,
    x: &Tensor,
    stats: &mut ContractionStats,
) -> Result<([Tensor; 3], QkvFusedCache)> {
    forward_qkv_fused_prec(wq, wk, wv, x, Precision::F32, stats)
}

/// [`forward_qkv_fused`] under the mixed-precision storage path (see
/// [`TTLinear::forward_prec`]): the shared Z2, the shared right chain
/// and the three left chains are rounded on store and packed to `prec`.
pub fn forward_qkv_fused_prec(
    wq: &TTLinear,
    wk: &TTLinear,
    wv: &TTLinear,
    x: &Tensor,
    prec: Precision,
    stats: &mut ContractionStats,
) -> Result<([Tensor; 3], QkvFusedCache)> {
    forward_qkv_fused_ckpt(wq, wk, wv, x, prec, CheckpointMode::CacheAll, stats)
}

/// [`forward_qkv_fused_prec`] under a gradient-checkpointing mode (see
/// [`TTLinear::forward_ckpt`]): `Recompute` retains only the rounded
/// layer input and lets [`backward_qkv_fused`] rebuild the shared
/// chains and Z2.
pub fn forward_qkv_fused_ckpt(
    wq: &TTLinear,
    wk: &TTLinear,
    wv: &TTLinear,
    x: &Tensor,
    prec: Precision,
    mode: CheckpointMode,
    stats: &mut ContractionStats,
) -> Result<([Tensor; 3], QkvFusedCache)> {
    // Hard precondition, checked in release builds too: running the
    // shared right merge over untied wk/wv would silently produce
    // wrong K/V projections, and the check is a few-KB compare vs
    // millions of multiplies per forward.
    if !qkv_input_cores_shared(wq, wk, wv) {
        return Err(anyhow!("fused QKV requires tied input-side cores across Q/K/V"));
    }
    let (qtt, ktt, vtt) = (wq.tt(), wk.tt(), wv.tt());
    let d = qtt.d();
    let (m, n) = (qtt.m(), qtt.n());
    if x.ndim() != 2 || x.shape[1] != n {
        return Err(anyhow!("x must be (K, {n}), got {:?}", x.shape));
    }
    let k_dim = x.shape[0];
    let r_d = qtt.ranks[d];

    // Shared input side (one right merge, one rounded Z2) and the
    // three left chains, through the shared builder — the same fused
    // fold order the `Recompute` backward re-runs.
    let xq = prec.round_tensor(x);
    let (left_chains, right_chain, z2) =
        build_qkv_states(&qtt, &ktt, &vtt, &xq, prec, true, stats)?;

    // Per-projection output applies.
    let mut ys = Vec::with_capacity(3);
    for (w, chain) in [wq, wk, wv].into_iter().zip(&left_chains) {
        let _sp = trace::span("ttlinear", "apply");
        let z3 = chain.last().expect("d >= 1");
        let y = z2.matmul(&z3.t()?)?; // (K, M)
        stats.record_step((k_dim * r_d * m) as u64, (k_dim * m) as u64, false);
        ys.push(ops::add_row(&y, &w.bias()));
    }
    let ys: [Tensor; 3] = ys.try_into().expect("three projections");
    let states = match mode {
        CheckpointMode::Recompute => None,
        CheckpointMode::CacheAll => Some(QkvFusedStates {
            left_chains: left_chains
                .map(|c| c.into_iter().map(|t| PackedTensor::pack_owned(t, prec)).collect()),
            right_chain: right_chain
                .into_iter()
                .map(|t| PackedTensor::pack_owned(t, prec))
                .collect(),
            z2: PackedTensor::pack_owned(z2, prec),
        }),
    };
    Ok((ys, QkvFusedCache { x: PackedTensor::pack_owned(xq, prec), states }))
}

/// Fused QKV backward: given the three output gradients, return `dX`
/// and the parameter gradients.  The input-side gradient flows through
/// one summed `dZ2 = sum_p dY_p Z3_p`, so `dZ1`, `dX` and the right
/// chain are each unrolled **once**; executed multiplies equal
/// `btt_qkv_bwd_muls` (2x the fused forward).
pub fn backward_qkv_fused(
    wq: &TTLinear,
    wk: &TTLinear,
    wv: &TTLinear,
    dq: &Tensor,
    dk: &Tensor,
    dv: &Tensor,
    cache: &QkvFusedCache,
    stats: &mut ContractionStats,
) -> Result<(Tensor, QkvFusedGrads)> {
    let (qtt, ktt, vtt) = (wq.tt(), wk.tt(), wv.tt());
    let d = qtt.d();
    let (m, n) = (qtt.m(), qtt.n());
    let r_d = qtt.ranks[d];
    let k_dim = cache.x.shape()[0];
    for dy in [dq, dk, dv] {
        if dy.ndim() != 2 || dy.shape[1] != m || dy.shape[0] != k_dim {
            return Err(anyhow!("dy must be ({k_dim}, {m}), got {:?}", dy.shape));
        }
    }

    // Widen-on-load: view the shared cache once (zero-copy borrows on
    // the f32 path; f32 accumulation throughout).  Under `Recompute`
    // the shared right chain, Z2 and the three left chains are rebuilt
    // from the stored input and the (still-unchanged) cores through the
    // forward's exact fold order and round-on-store precision —
    // bitwise the cached states per precision — and charged as
    // transient multiplies (`btt_qkv_recompute_muls`).
    let x = cache.x.view();
    let (left_chains, right_chain, z2): (
        [Vec<Cow<'_, Tensor>>; 3],
        Vec<Cow<'_, Tensor>>,
        Cow<'_, Tensor>,
    ) = match &cache.states {
        Some(s) => (
            [0usize, 1, 2].map(|p| s.left_chains[p].iter().map(PackedTensor::view).collect()),
            s.right_chain.iter().map(PackedTensor::view).collect(),
            s.z2.view(),
        ),
        None => {
            let prec = cache.x.precision();
            let (lefts, right, z2) =
                build_qkv_states(&qtt, &ktt, &vtt, x.as_ref(), prec, false, stats)?;
            (
                lefts.map(|c| c.into_iter().map(Cow::Owned).collect()),
                right.into_iter().map(Cow::Owned).collect(),
                Cow::Owned(z2),
            )
        }
    };
    let mut dz2 = Tensor::zeros(&[k_dim, r_d]);
    let mut m_grads = Vec::with_capacity(3);
    let mut biases = Vec::with_capacity(3);
    for (p, (tt, dy)) in [&qtt, &ktt, &vtt].into_iter().zip([dq, dk, dv]).enumerate() {
        let mut dbias = vec![0.0f32; m];
        for row in dy.data.chunks(m) {
            for (b, &v) in dbias.iter_mut().zip(row) {
                *b += v;
            }
        }
        biases.push(dbias);
        let left_chain = &left_chains[p];
        let z3 = left_chain.last().expect("d >= 1").as_ref();
        let dz3 = dy.t()?.matmul(z2.as_ref())?; // (M, r_d)
        stats.record_step((m * k_dim * r_d) as u64, (m * r_d) as u64, false);
        let part = dy.matmul(z3)?; // (K, r_d) contribution to dZ2
        stats.record_step((k_dim * m * r_d) as u64, (k_dim * r_d) as u64, false);
        dz2 = ops::add(&dz2, &part);
        m_grads.push(unroll_left_chain(tt, left_chain, dz3, stats)?);
    }

    // Shared input side, charged once.
    let z1 = right_chain.last().expect("d >= 1").as_ref();
    let dz1 = dz2.t()?.matmul(x.as_ref())?; // (r_d, N)
    stats.record_step((r_d * k_dim * n) as u64, (r_d * n) as u64, false);
    let dx = dz2.matmul(z1)?; // (K, N)
    stats.record_step((k_dim * r_d * n) as u64, (k_dim * n) as u64, false);
    let n_cores = unroll_right_chain(&qtt, &right_chain, dz1, stats)?;

    let m_cores: [Vec<Tensor>; 3] = m_grads.try_into().expect("three projections");
    let bias: [Vec<f32>; 3] = biases.try_into().expect("three projections");
    Ok((dx, QkvFusedGrads { m_cores, n_cores, bias }))
}

/// PU stage of the fused QKV layer: per-projection output cores and
/// biases step through their usual name-keyed slots; the shared input
/// cores take **one** optimizer step on the canonical slot (wq's name)
/// and the updated data is copied to the other two projections, keeping
/// them bitwise in lockstep with a 1x (not 3x) state footprint.
pub fn apply_update_qkv_fused(
    wq: &mut TTLinear,
    wk: &mut TTLinear,
    wv: &mut TTLinear,
    grads: &QkvFusedGrads,
    opt: &mut ModelOptim,
    layer_prefix: &str,
    hyper: &Hyper,
) {
    let d = wq.tt().d();
    {
        let mut one = |w: &mut TTLinear, name: &str, p: usize| {
            w.update_tt(|tt| {
                for k in 0..d {
                    opt.step(
                        &format!("{layer_prefix}.{name}.cores.{k}"),
                        &mut tt.cores[k].data,
                        &grads.m_cores[p][k].data,
                        hyper,
                    );
                }
            });
            w.update_bias(|b| {
                opt.step(&format!("{layer_prefix}.{name}.bias"), b, &grads.bias[p], hyper)
            });
        };
        one(wq, "wq", 0);
        one(wk, "wk", 1);
        one(wv, "wv", 2);
    }
    // Shared input cores: one optimizer step on the canonical (wq)
    // slot, then copy the updated values across.  The optimizer rounds
    // on store, so the copy and the repack are bitwise lossless and
    // the three projections stay in lockstep.
    wq.update_tt(|tt| {
        for k in 0..d {
            let c = d + k;
            opt.step(
                &format!("{layer_prefix}.wq.cores.{c}"),
                &mut tt.cores[c].data,
                &grads.n_cores[k].data,
                hyper,
            );
        }
    });
    let src = wq.tt();
    for w in [wk, wv] {
        w.update_tt(|tt| {
            for c in d..2 * d {
                tt.cores[c].data.copy_from_slice(&src.cores[c].data);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::LinearShape;
    use crate::util::rng::SplitMix64;

    fn layer(rng: &mut SplitMix64) -> TTLinear {
        TTLinear::randn(&[4, 3], &[3, 4], 3, 0.5, rng)
    }

    #[test]
    fn forward_matches_btt_contraction() {
        let mut rng = SplitMix64::new(51);
        let l = layer(&mut rng);
        let x = Tensor::randn(&[5, 12], 1.0, &mut rng); // (K, N)
        let mut stats = ContractionStats::default();
        let (y, _) = l.forward(&x, &mut stats).unwrap();
        // Column-major reference through the instrumented engine.
        let (y_cols, ref_stats) = l.tt().matmul_btt(&x.t().unwrap()).unwrap();
        let y_ref = ops::add_row(&y_cols.t().unwrap(), &l.bias());
        assert!(y.max_abs_diff(&y_ref) < 1e-4);
        assert_eq!(stats.muls, ref_stats.muls);
        assert_eq!(stats.stored_intermediate_elems, ref_stats.stored_intermediate_elems);
    }

    #[test]
    fn backward_stats_match_cost_model() {
        let mut rng = SplitMix64::new(52);
        let l = layer(&mut rng);
        let k_dim = 7usize;
        let x = Tensor::randn(&[k_dim, 12], 1.0, &mut rng);
        let shape = LinearShape {
            m_modes: l.tt().m_modes.clone(),
            n_modes: l.tt().n_modes.clone(),
            ranks: l.tt().ranks.clone(),
        };
        let mut fwd = ContractionStats::default();
        let (y, cache) = l.forward(&x, &mut fwd).unwrap();
        assert_eq!(fwd.muls, shape.btt_muls(k_dim as u64), "Eq.20");
        assert_eq!(
            fwd.stored_intermediate_elems,
            shape.btt_memory(k_dim as u64),
            "Eq.21"
        );
        assert_eq!(cache.stored_elems(), shape.btt_training_cache_elems(k_dim as u64));
        let dy = Tensor::randn(&[k_dim, y.shape[1]], 1.0, &mut rng);
        let mut bwd = ContractionStats::default();
        l.backward(&dy, &cache, &mut bwd).unwrap();
        assert_eq!(bwd.muls, shape.btt_bwd_muls(k_dim as u64), "BP = 2x Eq.20");
    }

    #[test]
    fn dx_matches_dense_gradient() {
        // dX = dY W_dense: the TT backward must agree with the dense
        // chain rule.
        let mut rng = SplitMix64::new(53);
        let l = layer(&mut rng);
        let x = Tensor::randn(&[6, 12], 1.0, &mut rng);
        let mut stats = ContractionStats::default();
        let (y, cache) = l.forward(&x, &mut stats).unwrap();
        let dy = Tensor::randn(&[6, y.shape[1]], 1.0, &mut rng);
        let (dx, grads) = l.backward(&dy, &cache, &mut stats).unwrap();
        let w = l.tt().to_dense().unwrap(); // (M, N)
        let dx_dense = dy.matmul(&w).unwrap();
        assert!(dx.max_abs_diff(&dx_dense) < 1e-4);
        // Bias gradient: column sums of dY.
        for j in 0..y.shape[1] {
            let want: f32 = (0..6).map(|i| dy.at2(i, j)).sum();
            assert!((grads.bias[j] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn optimizer_update_reduces_reconstruction_loss() {
        // PU-stage steps on L = ||Y - Y*||^2 / 2 must reduce L, for the
        // stateless and the stateful update rules alike (each at a
        // learning rate suited to its step-size semantics: momentum's
        // effective rate is lr / (1 - mu), Adam's step is ~lr itself).
        use crate::optim::{OptimConfig, OptimKind};
        for (kind, lr) in [
            (OptimKind::Sgd, 0.01f32),
            (OptimKind::Momentum, 0.003),
            (OptimKind::Adam, 0.05),
            (OptimKind::AdamW, 0.05),
        ] {
            let mut rng = SplitMix64::new(54);
            let mut l = layer(&mut rng);
            let x = Tensor::randn(&[8, 12], 1.0, &mut rng);
            let target = Tensor::randn(&[8, 12], 0.5, &mut rng);
            let mut opt = ModelOptim::new(OptimConfig { kind, ..Default::default() });
            let hyper = opt.hyper(lr);
            let mut first = None;
            let mut last = 0.0f32;
            for _ in 0..80 {
                let mut stats = ContractionStats::default();
                let (y, cache) = l.forward(&x, &mut stats).unwrap();
                let mut dy = y.clone();
                for (d, &t) in dy.data.iter_mut().zip(&target.data) {
                    *d -= t;
                }
                last = 0.5 * dy.norm().powi(2);
                first.get_or_insert(last);
                let (_, grads) = l.backward(&dy, &cache, &mut stats).unwrap();
                l.apply_update(&grads, &mut opt, "probe", &hyper);
            }
            assert!(last < 0.6 * first.unwrap(), "{kind:?}: loss {last} vs {first:?}");
            // One slot per core + bias, state sized by the rule.
            let elems: u64 = l.tt().cores.iter().map(|c| c.numel() as u64).sum::<u64>()
                + l.bias().len() as u64;
            assert_eq!(
                opt.allocated_state_elems(),
                kind.state_multiplier() as u64 * elems
            );
        }
    }

    #[test]
    fn f32_forward_prec_is_bitwise_the_plain_forward() {
        let mut rng = SplitMix64::new(55);
        let l = layer(&mut rng);
        let x = Tensor::randn(&[6, 12], 1.0, &mut rng);
        let mut s1 = ContractionStats::default();
        let (y1, c1) = l.forward(&x, &mut s1).unwrap();
        let mut s2 = ContractionStats::default();
        let (y2, c2) = l.forward_prec(&x, Precision::F32, &mut s2).unwrap();
        assert_eq!(y1.data, y2.data);
        assert_eq!(s1.muls, s2.muls);
        assert_eq!(c1.stored_elems(), c2.stored_elems());
        assert_eq!(c1.stored_bytes(), 4 * c1.stored_elems());
    }

    #[test]
    fn half_width_cache_halves_bytes_and_backward_stays_close() {
        // The bf16 cache stores the same element count at half the
        // bytes; the backward through the packed (rounded) cache stays
        // within half-precision tolerance of the f32 gradients; the
        // instrumented counts are precision-independent.
        let mut rng = SplitMix64::new(56);
        let l = layer(&mut rng);
        let x = Tensor::randn(&[6, 12], 1.0, &mut rng);
        let dy = Tensor::randn(&[6, 12], 1.0, &mut rng);
        let mut s32 = ContractionStats::default();
        let (y32, c32) = l.forward(&x, &mut s32).unwrap();
        let mut g32stats = ContractionStats::default();
        let (dx32, g32) = l.backward(&dy, &c32, &mut g32stats).unwrap();
        for prec in [Precision::Bf16, Precision::F16] {
            let mut s = ContractionStats::default();
            let (y, c) = l.forward_prec(&x, prec, &mut s).unwrap();
            assert_eq!(s.muls, s32.muls, "{prec:?}: muls must be precision-independent");
            assert_eq!(c.stored_elems(), c32.stored_elems());
            assert_eq!(2 * c.stored_bytes(), c32.stored_bytes(), "{prec:?}: not half-width");
            // Output within the storage format's relative error budget.
            let scale = y32.norm() / (y32.numel() as f32).sqrt();
            assert!(
                y.max_abs_diff(&y32) < 0.05 * (1.0 + scale),
                "{prec:?}: forward drifted {}",
                y.max_abs_diff(&y32)
            );
            // Backward through the rounded cache tracks the f32 grads.
            let mut bs = ContractionStats::default();
            let (dx, g) = l.backward(&dy, &c, &mut bs).unwrap();
            assert_eq!(bs.muls, g32stats.muls);
            let dscale = dx32.norm() / (dx32.numel() as f32).sqrt();
            assert!(dx.max_abs_diff(&dx32) < 0.05 * (1.0 + dscale), "{prec:?}: dX drifted");
            for (k, (a, b)) in g.cores.iter().zip(&g32.cores).enumerate() {
                let gs = b.norm() / (b.numel() as f32).sqrt();
                assert!(
                    a.max_abs_diff(b) < 0.05 * (1.0 + gs),
                    "{prec:?}: core {k} grad drifted {}",
                    a.max_abs_diff(b)
                );
            }
        }
    }

    #[test]
    fn recompute_backward_is_bitwise_the_cached_backward() {
        let mut rng = SplitMix64::new(57);
        let l = layer(&mut rng);
        let k_dim = 6usize;
        let x = Tensor::randn(&[k_dim, 12], 1.0, &mut rng);
        let dy = Tensor::randn(&[k_dim, 12], 1.0, &mut rng);
        let mut s_c = ContractionStats::default();
        let (y_c, cache) =
            l.forward_ckpt(&x, Precision::F32, CheckpointMode::CacheAll, &mut s_c).unwrap();
        let mut s_r = ContractionStats::default();
        let (y_r, ckpt) =
            l.forward_ckpt(&x, Precision::F32, CheckpointMode::Recompute, &mut s_r).unwrap();
        assert_eq!(y_c.data, y_r.data, "forward must not depend on the checkpoint mode");
        assert_eq!(s_c.muls, s_r.muls);
        assert_eq!(ckpt.mode(), CheckpointMode::Recompute);
        assert_eq!(ckpt.stored_elems(), 0, "recompute cache must retain nothing");
        assert!(cache.stored_bytes() > 0);
        let mut b_c = ContractionStats::default();
        let (dx_c, g_c) = l.backward(&dy, &cache, &mut b_c).unwrap();
        let mut b_r = ContractionStats::default();
        let (dx_r, g_r) = l.backward(&dy, &ckpt, &mut b_r).unwrap();
        assert_eq!(dx_c.data, dx_r.data, "dX diverged under recompute");
        for (a, b) in g_c.cores.iter().zip(&g_r.cores) {
            assert_eq!(a.data, b.data, "core grad diverged under recompute");
        }
        assert_eq!(g_c.bias, g_r.bias);
        // The rebuild is charged exactly as the cost model's FLOP delta
        // and never as stored intermediates.
        let shape = LinearShape {
            m_modes: l.tt().m_modes.clone(),
            n_modes: l.tt().n_modes.clone(),
            ranks: l.tt().ranks.clone(),
        };
        assert_eq!(b_r.muls, b_c.muls + shape.btt_recompute_muls(k_dim as u64));
        assert_eq!(b_r.stored_intermediate_elems, b_c.stored_intermediate_elems);
    }

    /// Random Q/K/V triplet with tied input-side cores (the fused-QKV
    /// precondition) at the tiny shape.
    fn fused_triplet(rng: &mut SplitMix64) -> (TTLinear, TTLinear, TTLinear) {
        let wq = layer(rng);
        let d = wq.tt().d();
        let mut wk = layer(rng);
        let mut wv = layer(rng);
        let src = wq.tt().into_owned();
        for w in [&mut wk, &mut wv] {
            w.update_tt(|tt| {
                for c in d..2 * d {
                    tt.cores[c] = src.cores[c].clone();
                }
            });
        }
        assert!(qkv_input_cores_shared(&wq, &wk, &wv));
        (wq, wk, wv)
    }

    #[test]
    fn fused_qkv_forward_matches_separate_and_costs_less() {
        let mut rng = SplitMix64::new(61);
        let (wq, wk, wv) = fused_triplet(&mut rng);
        let k_dim = 6usize;
        let x = Tensor::randn(&[k_dim, 12], 1.0, &mut rng);
        let mut fused = ContractionStats::default();
        let ([yq, yk, yv], cache) = forward_qkv_fused(&wq, &wk, &wv, &x, &mut fused).unwrap();
        let mut sep = ContractionStats::default();
        for (w, y) in [(&wq, &yq), (&wk, &yk), (&wv, &yv)] {
            let (y_ref, _) = w.forward(&x, &mut sep).unwrap();
            assert!(y.max_abs_diff(&y_ref) < 1e-6, "fused projection diverges");
        }
        // Fewer multiplies and fewer stored intermediates than 3x
        // separate, matching the new cost-model expressions.
        assert!(fused.muls < sep.muls, "{} !< {}", fused.muls, sep.muls);
        assert!(fused.stored_intermediate_elems < sep.stored_intermediate_elems);
        let shape = LinearShape {
            m_modes: wq.tt().m_modes.clone(),
            n_modes: wq.tt().n_modes.clone(),
            ranks: wq.tt().ranks.clone(),
        };
        assert_eq!(fused.muls, shape.btt_fwd_qkv_muls(k_dim as u64));
        assert_eq!(
            fused.stored_intermediate_elems,
            shape.btt_qkv_memory(k_dim as u64)
        );
        assert_eq!(cache.stored_elems(), shape.btt_qkv_memory(k_dim as u64));
    }

    #[test]
    fn fused_qkv_backward_matches_separate_and_costs_2x_forward() {
        let mut rng = SplitMix64::new(62);
        let (wq, wk, wv) = fused_triplet(&mut rng);
        let k_dim = 5usize;
        let x = Tensor::randn(&[k_dim, 12], 1.0, &mut rng);
        let mut stats = ContractionStats::default();
        let (_, cache) = forward_qkv_fused(&wq, &wk, &wv, &x, &mut stats).unwrap();
        let dq = Tensor::randn(&[k_dim, 12], 1.0, &mut rng);
        let dk = Tensor::randn(&[k_dim, 12], 1.0, &mut rng);
        let dv = Tensor::randn(&[k_dim, 12], 1.0, &mut rng);
        let mut bwd = ContractionStats::default();
        let (dx, grads) =
            backward_qkv_fused(&wq, &wk, &wv, &dq, &dk, &dv, &cache, &mut bwd).unwrap();
        let qtt = wq.tt().into_owned();
        let shape = LinearShape {
            m_modes: qtt.m_modes.clone(),
            n_modes: qtt.n_modes.clone(),
            ranks: qtt.ranks.clone(),
        };
        assert_eq!(bwd.muls, shape.btt_qkv_bwd_muls(k_dim as u64), "BP = 2x fused FP");

        // Reference: three separate backwards on the tied layers; dX and
        // the shared input-core gradients are the sums over projections.
        let d = qtt.d();
        let mut dx_ref = Tensor::zeros(&dx.shape);
        let mut n_ref: Vec<Tensor> =
            (d..2 * d).map(|c| Tensor::zeros(&qtt.cores[c].shape)).collect();
        for (p, (w, dy)) in [(&wq, &dq), (&wk, &dk), (&wv, &dv)].into_iter().enumerate() {
            let mut s = ContractionStats::default();
            let (_, c) = w.forward(&x, &mut s).unwrap();
            let (dx_p, g) = w.backward(dy, &c, &mut s).unwrap();
            dx_ref = ops::add(&dx_ref, &dx_p);
            for (k, acc) in n_ref.iter_mut().enumerate() {
                *acc = ops::add(acc, &g.cores[d + k]);
            }
            for k in 0..d {
                assert!(
                    grads.m_cores[p][k].max_abs_diff(&g.cores[k]) < 1e-5,
                    "proj {p} m-core {k} grad diverges"
                );
            }
            for (b, &want) in grads.bias[p].iter().zip(&g.bias) {
                assert!((b - want).abs() < 1e-5);
            }
        }
        assert!(dx.max_abs_diff(&dx_ref) < 1e-5, "dX diverges from summed separate");
        for (k, acc) in n_ref.iter().enumerate() {
            assert!(
                grads.n_cores[k].max_abs_diff(acc) < 1e-5,
                "shared n-core {k} grad != sum over projections"
            );
        }
    }

    #[test]
    fn fused_update_keeps_input_cores_in_lockstep() {
        use crate::optim::{OptimConfig, OptimKind};
        let mut rng = SplitMix64::new(63);
        let (mut wq, mut wk, mut wv) = fused_triplet(&mut rng);
        let x = Tensor::randn(&[4, 12], 1.0, &mut rng);
        let mut opt = ModelOptim::new(OptimConfig { kind: OptimKind::Adam, ..Default::default() });
        let hyper = opt.hyper(1e-2);
        for _ in 0..5 {
            let mut stats = ContractionStats::default();
            let (ys, cache) = forward_qkv_fused(&wq, &wk, &wv, &x, &mut stats).unwrap();
            let [dq, dk, dv] = ys; // dL/dy = y probes every path
            let (_, grads) =
                backward_qkv_fused(&wq, &wk, &wv, &dq, &dk, &dv, &cache, &mut stats).unwrap();
            apply_update_qkv_fused(&mut wq, &mut wk, &mut wv, &grads, &mut opt, "l", &hyper);
            assert!(
                qkv_input_cores_shared(&wq, &wk, &wv),
                "input cores drifted out of lockstep"
            );
        }
        // State: 3x (m-cores + bias) + 1x shared n-cores — not 3x.
        let qtt = wq.tt().into_owned();
        let d = qtt.d();
        let m_side: u64 = (0..d).map(|k| qtt.cores[k].numel() as u64).sum();
        let n_side: u64 = (d..2 * d).map(|c| qtt.cores[c].numel() as u64).sum();
        let distinct = 3 * (m_side + wq.bias().len() as u64) + n_side;
        assert_eq!(opt.allocated_state_elems(), 2 * distinct);
    }
}
