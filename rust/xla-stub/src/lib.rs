//! Offline stub of the `xla` PJRT binding.
//!
//! Mirrors the subset of the real binding's API that
//! `tt_trainer::runtime` uses, so the `pjrt` feature compiles without
//! libxla_extension.  Every operation that would reach a real PJRT
//! client fails with [`Error::Unavailable`] instead; constructors that
//! cannot fail return inert values.  See the workspace `Cargo.toml` for
//! how to substitute a real binding.

use std::path::Path;

/// Stub error: always "PJRT unavailable".
#[derive(Debug)]
pub enum Error {
    /// The operation needs a real PJRT runtime.
    Unavailable(&'static str),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: built against the offline `xla` stub; patch in a real \
                 PJRT binding to execute HLO artifacts"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can carry.
pub trait ArrayElement: Copy + Default {}

impl ArrayElement for f32 {}
impl ArrayElement for f64 {}
impl ArrayElement for i32 {}
impl ArrayElement for i64 {}

/// Host tensor (stub: carries no data).
#[derive(Debug, Clone)]
pub struct Literal(());

impl Literal {
    pub fn scalar<T: ArrayElement>(_v: T) -> Literal {
        Literal(())
    }

    pub fn vec1<T: ArrayElement>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::Unavailable("Literal::reshape"))
    }

    pub fn element_count(&self) -> usize {
        0
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable("Literal::to_tuple"))
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        Err(Error::Unavailable("Literal::to_tuple2"))
    }
}

/// npy/npz readers (on the real binding, a byte-level deserializer).
pub trait FromRawBytes: Sized {
    type Context;

    fn read_npy<P: AsRef<Path>>(path: P, ctx: &Self::Context) -> Result<Self>;

    fn read_npz<P: AsRef<Path>>(path: P, ctx: &Self::Context) -> Result<Vec<(String, Self)>>;
}

impl FromRawBytes for Literal {
    type Context = ();

    fn read_npy<P: AsRef<Path>>(_path: P, _ctx: &()) -> Result<Literal> {
        Err(Error::Unavailable("Literal::read_npy"))
    }

    fn read_npz<P: AsRef<Path>>(_path: P, _ctx: &()) -> Result<Vec<(String, Literal)>> {
        Err(Error::Unavailable("Literal::read_npz"))
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation handle (stub).
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device buffer handle (stub).
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// PJRT client (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }
}

/// Compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute"))
    }
}
