//! Cross-language parity: the rust generator must produce exactly the
//! utterances pinned in `python/tests/test_data_parity.py`.

use tt_trainer::config::ModelConfig;
use tt_trainer::data::{Dataset, Generator};

#[test]
fn pinned_utterances_seed42() {
    let mut g = Generator::new(42);
    let u1 = g.utterance();
    assert_eq!(u1.words.join(" "), "which airline operates flight two");
    assert_eq!(u1.intent, 18);
    assert_eq!(u1.labels, vec![0, 0, 0, 0, 21]);

    let u2 = g.utterance();
    assert_eq!(u2.words.join(" "), "tell me about continental");
    assert_eq!(u2.intent, 3);
    assert_eq!(u2.labels, vec![0, 0, 0, 15]);

    let u3 = g.utterance();
    assert_eq!(
        u3.words.join(" "),
        "i want to fly from new york to dallas in the noon"
    );
    assert_eq!(u3.intent, 0);
    assert_eq!(u3.labels, vec![0, 0, 0, 0, 0, 1, 2, 0, 3, 0, 0, 11]);
}

#[test]
fn pinned_encoding_seed42() {
    let cfg = ModelConfig::paper(2);
    let ds = Dataset::synth(&cfg, 42, 1);
    let ex = &ds.examples[0];
    assert_eq!(&ex.tokens[..6], &[1, 193, 9, 135, 75, 183]);
    assert_eq!(ex.intent, 18);
    assert!(ex.tokens[6..].iter().all(|&t| t == 0));
}

#[test]
fn vocab_matches_python_count() {
    let cfg = ModelConfig::paper(2);
    let ds = Dataset::synth(&cfg, 1, 1);
    // python/tests/test_data_parity.py sees 198 words incl. specials.
    assert_eq!(ds.tokenizer.vocab_used(), 198);
}
