//! Gradient-checkpointing parity and memory-accounting suite — all
//! runnable with no artifacts:
//!
//! * recompute-vs-cached gradients are **bitwise identical** at f32
//!   (the rebuilt chain states take the same deterministic fold order)
//!   for TTLinear, the fused QKV pass and the whole model, and stay
//!   within tolerance at bf16/f16,
//! * gradients finite-difference-check (< 1e-3) through the recompute
//!   path for TTLinear, fused QKV and the TTM embedding,
//! * a 24-step Adam loss trajectory under `Recompute` (and a
//!   `PerLayer` mix) is bitwise the `CacheAll` trajectory,
//! * memory accounting: `stored_bytes()` under `Recompute` is strictly
//!   below `CacheAll` for random shapes/depths/precisions, and
//!   `ResourceReport::eq21_cache_bytes` equals the sum of the live
//!   caches' `stored_bytes()` — the caches are the single source of
//!   truth the resource model is pinned to,
//! * `--checkpoint` composes with `--init-ckpt` and `--optimizer adam`
//!   resume: the policy survives `load_checkpoint` and resumed
//!   trajectories stay bitwise in lockstep across policies.

use tt_trainer::config::ModelConfig;
use tt_trainer::coordinator::TrainBackend;
use tt_trainer::costmodel::LinearShape;
use tt_trainer::fpga::resources;
use tt_trainer::inference::ParamMap;
use tt_trainer::optim::{OptimConfig, OptimKind};
use tt_trainer::tensor::{ContractionStats, Precision, Tensor};
use tt_trainer::train::{
    backward_qkv_fused, forward_qkv_fused_ckpt, qkv_input_cores_shared, CheckpointMode,
    CheckpointPolicy, NativeTrainModel, NativeTrainer, TTLinear,
};
use tt_trainer::util::prop;
use tt_trainer::util::rng::SplitMix64;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        n_layers: 1,
        d_hid: 48,
        n_heads: 4,
        seq_len: 8,
        batch: 1,
        vocab: 27,
        n_intents: 5,
        n_slots: 7,
        tt_m: vec![4, 4, 3],
        tt_n: vec![3, 4, 4],
        tt_rank: 3,
        ttm_vocab_modes: vec![3, 3, 3],
        ttm_hid_modes: vec![4, 4, 3],
        ttm_rank: 4,
        pad_id: 0,
        cls_id: 1,
        unk_id: 2,
    }
}

/// Two fixed examples at the tiny config (tokens, intents, slots).
fn two_examples() -> (Vec<i32>, Vec<i32>, Vec<i32>) {
    let tokens = vec![
        1, 5, 9, 13, 4, 0, 0, 0, // example 0
        1, 3, 2, 7, 11, 26, 6, 0, // example 1
    ];
    let intents = vec![2, 4];
    let slots = vec![
        0, 1, 2, 3, 1, 0, 0, 0, //
        0, 2, 2, 4, 5, 6, 1, 0, //
    ];
    (tokens, intents, slots)
}

/// Random Q/K/V triplet with tied input-side cores (the fused-QKV
/// precondition) at a tiny shape.
fn fused_triplet(rng: &mut SplitMix64) -> (TTLinear, TTLinear, TTLinear) {
    let layer = |rng: &mut SplitMix64| TTLinear::randn(&[4, 3], &[3, 4], 3, 0.5, rng);
    let wq = layer(rng);
    let mut wk = layer(rng);
    let mut wv = layer(rng);
    let src = wq.tt().into_owned();
    let d = src.d();
    for w in [&mut wk, &mut wv] {
        w.update_tt(|tt| {
            for c in d..2 * d {
                tt.cores[c] = src.cores[c].clone();
            }
        });
    }
    assert!(qkv_input_cores_shared(&wq, &wk, &wv));
    (wq, wk, wv)
}

// ---------------------------------------------------------------------------
// Bitwise parity: recomputed states take the same fold order
// ---------------------------------------------------------------------------

#[test]
fn fused_qkv_recompute_grads_bitwise_identical_at_f32() {
    let mut rng = SplitMix64::new(71);
    let (wq, wk, wv) = fused_triplet(&mut rng);
    let k_dim = 5usize;
    let x = Tensor::randn(&[k_dim, 12], 1.0, &mut rng);
    let dq = Tensor::randn(&[k_dim, 12], 1.0, &mut rng);
    let dk = Tensor::randn(&[k_dim, 12], 1.0, &mut rng);
    let dv = Tensor::randn(&[k_dim, 12], 1.0, &mut rng);
    let run = |mode: CheckpointMode| {
        let mut s = ContractionStats::default();
        let (ys, cache) =
            forward_qkv_fused_ckpt(&wq, &wk, &wv, &x, Precision::F32, mode, &mut s).unwrap();
        let mut bwd = ContractionStats::default();
        let (dx, grads) =
            backward_qkv_fused(&wq, &wk, &wv, &dq, &dk, &dv, &cache, &mut bwd).unwrap();
        (ys, cache.stored_bytes(), dx, grads, bwd)
    };
    let (ys_c, bytes_c, dx_c, g_c, b_c) = run(CheckpointMode::CacheAll);
    let (ys_r, bytes_r, dx_r, g_r, b_r) = run(CheckpointMode::Recompute);
    for (a, b) in ys_c.iter().zip(&ys_r) {
        assert_eq!(a.data, b.data, "fused forward must not depend on the mode");
    }
    assert_eq!(bytes_r, 0, "recompute cache must retain nothing");
    assert!(bytes_c > 0);
    assert_eq!(dx_c.data, dx_r.data, "dX diverged under recompute");
    for p in 0..3 {
        for (a, b) in g_c.m_cores[p].iter().zip(&g_r.m_cores[p]) {
            assert_eq!(a.data, b.data, "proj {p} m-core grad diverged");
        }
        assert_eq!(g_c.bias[p], g_r.bias[p]);
    }
    for (a, b) in g_c.n_cores.iter().zip(&g_r.n_cores) {
        assert_eq!(a.data, b.data, "shared n-core grad diverged");
    }
    // The rebuild is charged exactly as the fused recompute-FLOP delta.
    let shape = LinearShape {
        m_modes: wq.tt().m_modes.clone(),
        n_modes: wq.tt().n_modes.clone(),
        ranks: wq.tt().ranks.clone(),
    };
    assert_eq!(b_r.muls, b_c.muls + shape.btt_qkv_recompute_muls(k_dim as u64));
    assert_eq!(b_r.stored_intermediate_elems, b_c.stored_intermediate_elems);
}

#[test]
fn half_precision_recompute_grads_stay_within_tolerance() {
    // The acceptance bar at bf16/f16 is within-tolerance (the rebuilt
    // states actually reproduce the rounded cached ones exactly, so
    // these bounds are loose).
    let mut rng = SplitMix64::new(72);
    let l = TTLinear::randn(&[4, 3], &[3, 4], 3, 0.5, &mut rng);
    let x = Tensor::randn(&[6, 12], 1.0, &mut rng);
    let dy = Tensor::randn(&[6, 12], 1.0, &mut rng);
    for prec in [Precision::Bf16, Precision::F16] {
        let run = |mode: CheckpointMode| {
            let mut s = ContractionStats::default();
            let (_, cache) = l.forward_ckpt(&x, prec, mode, &mut s).unwrap();
            let mut b = ContractionStats::default();
            l.backward(&dy, &cache, &mut b).unwrap()
        };
        let (dx_c, g_c) = run(CheckpointMode::CacheAll);
        let (dx_r, g_r) = run(CheckpointMode::Recompute);
        let scale = dx_c.norm() / (dx_c.numel() as f32).sqrt();
        assert!(
            dx_r.max_abs_diff(&dx_c) < 0.01 * (1.0 + scale),
            "{prec:?}: dX drifted {}",
            dx_r.max_abs_diff(&dx_c)
        );
        for (k, (a, b)) in g_r.cores.iter().zip(&g_c.cores).enumerate() {
            let gs = b.norm() / (b.numel() as f32).sqrt();
            assert!(
                a.max_abs_diff(b) < 0.01 * (1.0 + gs),
                "{prec:?}: core {k} grad drifted {}",
                a.max_abs_diff(b)
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Finite differences through the recompute path
// ---------------------------------------------------------------------------

#[test]
fn tt_linear_fd_gradients_through_recompute() {
    // Acceptance: relative error < 1e-3 through the recompute path.
    let mut rng = SplitMix64::new(73);
    let mut layer = TTLinear::randn(&[3, 2], &[2, 3], 2, 0.5, &mut rng);
    let x = Tensor::randn(&[4, 6], 1.0, &mut rng);
    let probe = Tensor::randn(&[4, 6], 1.0, &mut rng); // loss = <probe, y>
    let loss = |l: &TTLinear| -> f32 {
        let mut stats = ContractionStats::default();
        let (y, _) = l.forward(&x, &mut stats).unwrap();
        y.data.iter().zip(&probe.data).map(|(a, b)| a * b).sum()
    };
    let mut stats = ContractionStats::default();
    let (_, cache) = layer
        .forward_ckpt(&x, Precision::F32, CheckpointMode::Recompute, &mut stats)
        .unwrap();
    let (_, grads) = layer.backward(&probe, &cache, &mut stats).unwrap();
    let eps = 1e-2f32;
    for k in 0..layer.tt().cores.len() {
        for idx in 0..layer.tt().cores[k].numel() {
            let orig = layer.tt().cores[k].data[idx];
            layer.update_tt(|tt| tt.cores[k].data[idx] = orig + eps);
            let up = loss(&layer);
            layer.update_tt(|tt| tt.cores[k].data[idx] = orig - eps);
            let dn = loss(&layer);
            layer.update_tt(|tt| tt.cores[k].data[idx] = orig);
            let fd = (up - dn) / (2.0 * eps);
            let an = grads.cores[k].data[idx];
            let rel = (fd - an).abs() / (1.0 + an.abs());
            assert!(rel < 1e-3, "core {k}[{idx}]: fd {fd} vs analytic {an} (rel {rel})");
        }
    }
}

#[test]
fn fused_qkv_fd_gradients_through_recompute() {
    let mut rng = SplitMix64::new(74);
    let (mut wq, mut wk, mut wv) = fused_triplet(&mut rng);
    let d = wq.tt().d();
    let x = Tensor::randn(&[4, 12], 1.0, &mut rng);
    let probes: Vec<Tensor> = (0..3).map(|_| Tensor::randn(&[4, 12], 1.0, &mut rng)).collect();
    let loss = |wq: &TTLinear, wk: &TTLinear, wv: &TTLinear| -> f32 {
        let mut s = ContractionStats::default();
        let (ys, _) = forward_qkv_fused_ckpt(
            wq,
            wk,
            wv,
            &x,
            Precision::F32,
            CheckpointMode::CacheAll,
            &mut s,
        )
        .unwrap();
        ys.iter()
            .zip(&probes)
            .map(|(y, p)| y.data.iter().zip(&p.data).map(|(a, b)| a * b).sum::<f32>())
            .sum()
    };
    let mut s = ContractionStats::default();
    let (_, cache) = forward_qkv_fused_ckpt(
        &wq,
        &wk,
        &wv,
        &x,
        Precision::F32,
        CheckpointMode::Recompute,
        &mut s,
    )
    .unwrap();
    let (_, grads) = backward_qkv_fused(
        &wq, &wk, &wv, &probes[0], &probes[1], &probes[2], &cache, &mut s,
    )
    .unwrap();
    let eps = 1e-2f32;
    // Output-side (per-projection) cores: perturb wq only.
    for k in 0..d {
        for idx in 0..wq.tt().cores[k].numel() {
            let orig = wq.tt().cores[k].data[idx];
            wq.update_tt(|tt| tt.cores[k].data[idx] = orig + eps);
            let up = loss(&wq, &wk, &wv);
            wq.update_tt(|tt| tt.cores[k].data[idx] = orig - eps);
            let dn = loss(&wq, &wk, &wv);
            wq.update_tt(|tt| tt.cores[k].data[idx] = orig);
            let fd = (up - dn) / (2.0 * eps);
            let an = grads.m_cores[0][k].data[idx];
            let rel = (fd - an).abs() / (1.0 + an.abs());
            assert!(rel < 1e-3, "m-core {k}[{idx}]: fd {fd} vs {an} (rel {rel})");
        }
    }
    // Tied input-side cores are one parameter: perturb all three copies
    // together; the analytic gradient is the summed n_cores slot.
    for k in 0..d {
        let c = d + k;
        for idx in 0..wq.tt().cores[c].numel() {
            let orig = wq.tt().cores[c].data[idx];
            for w in [&mut wq, &mut wk, &mut wv] {
                w.update_tt(|tt| tt.cores[c].data[idx] = orig + eps);
            }
            let up = loss(&wq, &wk, &wv);
            for w in [&mut wq, &mut wk, &mut wv] {
                w.update_tt(|tt| tt.cores[c].data[idx] = orig - eps);
            }
            let dn = loss(&wq, &wk, &wv);
            for w in [&mut wq, &mut wk, &mut wv] {
                w.update_tt(|tt| tt.cores[c].data[idx] = orig);
            }
            let fd = (up - dn) / (2.0 * eps);
            let an = grads.n_cores[k].data[idx];
            let rel = (fd - an).abs() / (1.0 + an.abs());
            assert!(rel < 1e-3, "n-core {c}[{idx}]: fd {fd} vs {an} (rel {rel})");
        }
    }
}

#[test]
fn whole_model_fd_gradients_through_recompute_cover_ttm_embedding() {
    // End-to-end chain rule under the Recompute policy, spot-checked
    // against central differences — including a TTM embedding core
    // (whose chain is rebuilt per unique token in the VJP) and the
    // pooler (the aux cache).
    let cfg = tiny_cfg();
    let tokens = vec![1, 5, 5, 9, 4, 0, 0, 0]; // repeated + pad tokens
    let intent = vec![2];
    let slots = vec![0, 1, 2, 3, 1, 0, 0, 0];
    let loss_of = |params: &ParamMap| -> f32 {
        let mut probe = NativeTrainer::from_params(&cfg, params)
            .unwrap()
            .with_checkpoint(CheckpointPolicy::Recompute);
        probe.train_step(&tokens, &intent, &slots, 0.0).unwrap().loss
    };
    let base = NativeTrainer::random_init(&cfg, 75).unwrap();
    let before = base.model.to_params();
    // Analytic gradients via one lr=1 SGD step through the recompute
    // path: g = p - p'.
    let mut stepped = NativeTrainer::from_params(&cfg, &before)
        .unwrap()
        .with_checkpoint(CheckpointPolicy::Recompute);
    stepped.train_step(&tokens, &intent, &slots, 1.0).unwrap();
    let after = stepped.model.to_params();

    let eps = 2e-2f32;
    for (name, picks) in [
        ("embed.ttm.1", vec![1usize, 40, 100]),
        ("layers.0.wq.cores.2", vec![0usize, 10, 26]),
        ("layers.0.w2.cores.0", vec![0usize, 5]),
        ("cls.pool.cores.1", vec![0usize, 7]),
    ] {
        let (_, before_data) = &before[name];
        let (_, after_data) = &after[name];
        for idx in picks {
            let analytic = before_data[idx] - after_data[idx]; // g = p - p'
            let mut probe_map = before.clone();
            probe_map.get_mut(name).unwrap().1[idx] = before_data[idx] + eps;
            let up = loss_of(&probe_map);
            probe_map.get_mut(name).unwrap().1[idx] = before_data[idx] - eps;
            let dn = loss_of(&probe_map);
            let fd = (up - dn) / (2.0 * eps);
            assert!(
                (fd - analytic).abs() < 5e-3 * (1.0 + analytic.abs()),
                "{name}[{idx}]: fd {fd} vs analytic {analytic}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Whole-model trajectory identity
// ---------------------------------------------------------------------------

/// 24 batched Adam steps at f32 under a checkpoint policy: per-step
/// losses + final parameters.
fn adam_trajectory(policy: CheckpointPolicy) -> (Vec<f32>, ParamMap) {
    let (tokens, intents, slots) = two_examples();
    let mut t = NativeTrainer::random_init(&tiny_cfg(), 76)
        .unwrap()
        .with_optim(OptimConfig { kind: OptimKind::Adam, ..Default::default() })
        .with_checkpoint(policy);
    let losses = (0..24)
        .map(|_| t.train_step(&tokens, &intents, &slots, 1e-2).unwrap().loss)
        .collect();
    (losses, t.model.to_params())
}

#[test]
fn recompute_loss_trajectory_is_bitwise_the_cached_one() {
    // Acceptance: f32 gradients bitwise identical between the policies
    // => the whole 24-step Adam trajectory (losses and parameters) is
    // bitwise identical, for full Recompute and for a PerLayer mix.
    let (ca_losses, ca_params) = adam_trajectory(CheckpointPolicy::CacheAll);
    let (re_losses, re_params) = adam_trajectory(CheckpointPolicy::Recompute);
    assert_eq!(ca_losses, re_losses, "recompute trajectory diverged");
    assert_eq!(ca_params, re_params, "recompute parameters diverged");
    let (pl_losses, pl_params) =
        adam_trajectory(CheckpointPolicy::PerLayer(vec![CheckpointMode::Recompute]));
    assert_eq!(ca_losses, pl_losses, "per-layer trajectory diverged");
    assert_eq!(ca_params, pl_params);
    // And the run actually trains.
    assert!(ca_losses.len() == 24);
    assert!(
        *ca_losses.last().unwrap() < 0.9 * ca_losses[0],
        "trajectory did not train: {} -> {}",
        ca_losses[0],
        ca_losses.last().unwrap()
    );
}

#[test]
fn bf16_recompute_trajectory_tracks_bf16_cached() {
    // At half precision the recomputed states reproduce the rounded
    // cached ones, so the trajectories stay (at least) within a tight
    // tolerance of each other.
    let (tokens, intents, slots) = two_examples();
    let run = |policy: CheckpointPolicy| -> Vec<f32> {
        let mut t = NativeTrainer::random_init(&tiny_cfg(), 77)
            .unwrap()
            .with_optim(OptimConfig {
                kind: OptimKind::Adam,
                precision: Precision::Bf16,
                ..Default::default()
            })
            .with_checkpoint(policy);
        (0..12).map(|_| t.train_step(&tokens, &intents, &slots, 1e-2).unwrap().loss).collect()
    };
    let ca = run(CheckpointPolicy::CacheAll);
    let re = run(CheckpointPolicy::Recompute);
    for (step, (a, b)) in ca.iter().zip(&re).enumerate() {
        let rel = (a - b).abs() / (1.0 + a.abs());
        assert!(rel < 1e-3, "step {step}: bf16 recompute drifted {a} vs {b}");
    }
}

// ---------------------------------------------------------------------------
// Memory accounting: the caches are the single source of truth
// ---------------------------------------------------------------------------

#[test]
fn stored_bytes_under_recompute_strictly_below_cacheall() {
    // Property over random shapes, depths, ranks, K and precisions.
    prop::check(78, 20, |rng| {
        let d = 1 + rng.below(3) as usize;
        let m_modes: Vec<usize> = (0..d).map(|_| 2 + rng.below(4) as usize).collect();
        let n_modes: Vec<usize> = (0..d).map(|_| 2 + rng.below(4) as usize).collect();
        let rank = 1 + rng.below(5) as usize;
        let k_dim = 1 + rng.below(12) as usize;
        let prec = Precision::all()[rng.below(3) as usize];
        let l = TTLinear::randn(&m_modes, &n_modes, rank, 0.5, rng);
        let x = Tensor::randn(&[k_dim, l.tt().n()], 1.0, rng);
        let mut s = ContractionStats::default();
        let (_, ca) = l.forward_ckpt(&x, prec, CheckpointMode::CacheAll, &mut s).unwrap();
        let (_, re) = l.forward_ckpt(&x, prec, CheckpointMode::Recompute, &mut s).unwrap();
        assert!(
            re.stored_bytes() < ca.stored_bytes(),
            "recompute {} !< cacheall {} (d={d}, rank={rank}, K={k_dim}, {prec:?})",
            re.stored_bytes(),
            ca.stored_bytes()
        );
        assert_eq!(re.stored_elems(), 0);
        // Both modes agree with the analytic checkpointed-byte forms.
        let shape = LinearShape {
            m_modes: l.tt().m_modes.clone(),
            n_modes: l.tt().n_modes.clone(),
            ranks: l.tt().ranks.clone(),
        };
        assert_eq!(ca.stored_elems(), shape.btt_training_cache_elems(k_dim as u64));
        assert_eq!(
            ca.stored_bytes(),
            shape.btt_memory_bytes_checkpointed(k_dim as u64, prec, false)
        );
        assert_eq!(
            re.stored_bytes(),
            shape.btt_memory_bytes_checkpointed(k_dim as u64, prec, true)
        );
    });
}

#[test]
fn resource_report_eq21_equals_sum_of_live_cache_bytes() {
    // The report's analytic eq21_cache_bytes must equal the executed
    // sum of the live caches' stored_bytes() for every (depth, batch,
    // precision, policy) — the caches are the single source of truth,
    // not a parallel formula that can drift.
    let policies = [
        CheckpointPolicy::CacheAll,
        CheckpointPolicy::Recompute,
        CheckpointPolicy::PerLayer(vec![CheckpointMode::Recompute]),
    ];
    let mut measured_by_policy = Vec::new();
    for n_layers in [1usize, 2] {
        for batch in [1usize, 2] {
            let mut cfg = tiny_cfg();
            cfg.n_layers = n_layers;
            cfg.batch = batch;
            let (tokens2, _, _) = two_examples();
            let tokens = &tokens2[..batch * cfg.seq_len];
            for prec in Precision::all() {
                for policy in &policies {
                    let mut model = NativeTrainModel::random_init(&cfg, 79).unwrap();
                    model.set_precision(prec);
                    model.checkpoint = policy.clone();
                    let measured = model.measure_eq21_cache_bytes(tokens).unwrap();
                    let report = resources::report_for_policy(
                        &cfg,
                        OptimKind::Adam,
                        prec,
                        policy,
                    );
                    assert_eq!(
                        measured, report.eq21_cache_bytes,
                        "L{n_layers} B{batch} {prec:?} {}: measured vs report",
                        policy.name()
                    );
                    if n_layers == 2 && batch == 1 && prec == Precision::F32 {
                        measured_by_policy.push(measured);
                    }
                }
            }
        }
    }
    // Strict ordering at L2/f32: recompute < per-layer mix < cache-all.
    let (ca, re, pl) = (measured_by_policy[0], measured_by_policy[1], measured_by_policy[2]);
    assert!(re < pl && pl < ca, "expected {re} < {pl} < {ca}");
    assert_eq!(re, 0, "full recompute retains no Eq. 21 cache");
}

#[test]
fn paper_config_report_matches_measured_caches() {
    // Same single-source-of-truth check at the real paper shape (L2,
    // seq 32): the U50 report's eq21 field is exactly what the native
    // trainer stores.
    let cfg = ModelConfig::paper(2);
    let mut tokens = vec![1i32, 5, 9, 13, 4, 7, 11, 2];
    tokens.resize(cfg.seq_len, 0);
    for policy in [CheckpointPolicy::CacheAll, CheckpointPolicy::Recompute] {
        let mut model = NativeTrainModel::random_init(&cfg, 80).unwrap();
        model.checkpoint = policy.clone();
        let measured = model.measure_eq21_cache_bytes(&tokens).unwrap();
        let report = resources::report_for_policy(&cfg, OptimKind::Adam, Precision::F32, &policy);
        assert_eq!(measured, report.eq21_cache_bytes, "policy {}", policy.name());
    }
}

// ---------------------------------------------------------------------------
// Checkpoint-file resume: --checkpoint x --init-ckpt x --optimizer adam
// ---------------------------------------------------------------------------

#[test]
fn checkpoint_policy_composes_with_init_ckpt_and_adam_resume() {
    // The regression the PR fixes: the policy is applied before the
    // checkpoint load (like the PR 4 --precision ordering) and must
    // survive load_checkpoint; resumed Adam trajectories stay bitwise
    // in lockstep — including across policies, since f32 gradients are
    // policy-independent.
    let cfg = tiny_cfg();
    let (tokens, intents, slots) = two_examples();
    let adam = OptimConfig { kind: OptimKind::Adam, ..Default::default() };
    let mut a = NativeTrainer::random_init(&cfg, 81)
        .unwrap()
        .with_optim(adam.clone())
        .with_checkpoint(CheckpointPolicy::Recompute);
    for _ in 0..3 {
        a.train_step(&tokens, &intents, &slots, 1e-2).unwrap();
    }
    let dir = std::env::temp_dir().join(format!("ckpt_policy_{}", std::process::id()));
    a.save_checkpoint(&dir).unwrap();

    // Resume with the policy configured before the load (CLI ordering).
    let mut b = NativeTrainer::random_init(&cfg, 99)
        .unwrap()
        .with_optim(adam.clone())
        .with_checkpoint(CheckpointPolicy::Recompute);
    b.load_checkpoint(&dir).unwrap();
    assert_eq!(
        b.model.checkpoint,
        CheckpointPolicy::Recompute,
        "policy lost across load_checkpoint"
    );
    assert_eq!(a.model.to_params(), b.model.to_params(), "params differ after load");
    assert_eq!(
        a.model.optim.allocated_state_elems(),
        b.model.optim.allocated_state_elems(),
        "Adam moments not restored"
    );
    // A CacheAll resume of the same checkpoint stays in lockstep too.
    let mut c = NativeTrainer::random_init(&cfg, 7).unwrap().with_optim(adam);
    c.load_checkpoint(&dir).unwrap();
    for step in 0..2 {
        a.train_step(&tokens, &intents, &slots, 1e-2).unwrap();
        b.train_step(&tokens, &intents, &slots, 1e-2).unwrap();
        c.train_step(&tokens, &intents, &slots, 1e-2).unwrap();
        assert_eq!(
            a.model.to_params(),
            b.model.to_params(),
            "recompute resume diverged at step {step}"
        );
        assert_eq!(
            a.model.to_params(),
            c.model.to_params(),
            "cross-policy resume diverged at step {step}"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}
