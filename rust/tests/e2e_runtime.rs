//! End-to-end integration over the real PJRT runtime: load the AOT
//! artifacts, train, evaluate, checkpoint.  Needs the `pjrt` feature;
//! each test skips itself when `make artifacts` has not been run.
//!
//! These tests share one PJRT client-backed engine per variant (compiling
//! the HLO dominates the cost) and run serially within each test.
#![cfg(feature = "pjrt")]

use tt_trainer::coordinator::Trainer;
use tt_trainer::data::Dataset;
use tt_trainer::runtime::{Engine, Manifest};

fn manifest() -> Option<Manifest> {
    match Manifest::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")) {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("skipping: artifacts/ not present (run `make artifacts`)");
            None
        }
    }
}

/// Load an engine, or skip gracefully — the `xla` dependency may be the
/// vendored type-check stub, whose PJRT client never comes up.
fn load_engine(spec: &tt_trainer::runtime::VariantSpec) -> Option<Engine> {
    match Engine::load(spec) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping: PJRT unavailable ({e})");
            None
        }
    }
}

#[test]
fn manifest_lists_all_paper_variants() {
    let Some(m) = manifest() else { return };
    for name in ["tt_L2", "tt_L4", "tt_L6", "mm_L2", "mm_L4", "mm_L6"] {
        let v = m.variant(name).unwrap();
        assert!(v.train_hlo.exists(), "{name}: missing train hlo");
        assert!(v.eval_hlo.exists(), "{name}: missing eval hlo");
        assert!(v.init_npz.exists(), "{name}: missing init npz");
        assert!(!v.params.is_empty());
    }
}

#[test]
fn compression_ratios_match_table3_shape() {
    let Some(m) = manifest() else { return };
    for (name, paper) in [("tt_L2", 30.5), ("tt_L4", 43.4), ("tt_L6", 52.0)] {
        let v = m.variant(name).unwrap();
        let ratio = v.compression_ratio();
        assert!(
            (ratio - paper).abs() / paper < 0.15,
            "{name}: {ratio:.1}x vs paper {paper}x"
        );
    }
    // Tensorized artifacts are ~MB scale (paper: 1.2-1.8 MB).
    for name in ["tt_L2", "tt_L4", "tt_L6"] {
        let v = m.variant(name).unwrap();
        assert!(v.size_mb() < 2.5, "{name}: {:.2} MB", v.size_mb());
    }
}

#[test]
fn tt_l2_trains_and_evaluates() {
    let Some(m) = manifest() else { return };
    let spec = m.variant("tt_L2").unwrap();
    let Some(engine) = load_engine(spec) else { return };
    let cfg = spec.config.clone();
    let data = Dataset::synth(&cfg, 42, 32);
    let mut trainer = Trainer::new(engine, 4e-3);

    // Loss must drop over a few dozen steps on a small repeated set.
    trainer.train_steps(&data, 8).unwrap();
    let early = trainer.metrics.recent_loss(8);
    trainer.train_steps(&data, 40).unwrap();
    let late = trainer.metrics.recent_loss(8);
    assert!(
        late < early,
        "loss did not decrease: early {early:.4} late {late:.4}"
    );

    // Eval output shapes + finite logits.
    let (il, sl) = trainer.backend.eval(&data.examples[0].tokens).unwrap();
    assert_eq!(il.len(), cfg.n_intents);
    assert_eq!(sl.len(), cfg.seq_len * cfg.n_slots);
    assert!(il.iter().all(|x| x.is_finite()));

    // Accuracy harness runs.
    let ev = trainer.evaluate(&data, Some(16)).unwrap();
    assert!(ev.intent_acc >= 0.0 && ev.intent_acc <= 1.0);
    assert!(ev.slot_acc >= 0.0 && ev.slot_acc <= 1.0);
}

#[test]
fn checkpoint_roundtrip_preserves_params() {
    let Some(m) = manifest() else { return };
    let spec = m.variant("tt_L2").unwrap();
    let Some(mut engine) = load_engine(spec) else { return };
    let cfg = spec.config.clone();
    let data = Dataset::synth(&cfg, 1, 4);
    let ex = &data.examples[0];
    engine
        .train_step(&ex.tokens, &[ex.intent], &ex.slots, 4e-3)
        .unwrap();

    let dir = std::env::temp_dir().join(format!("tt_ckpt_{}", std::process::id()));
    engine.save_checkpoint(&dir).unwrap();
    let before: Vec<Vec<f32>> = engine
        .params()
        .iter()
        .map(|l| l.to_vec::<f32>().unwrap())
        .collect();

    // Perturb by training more, then restore.
    engine
        .train_step(&ex.tokens, &[ex.intent], &ex.slots, 0.5)
        .unwrap();
    engine.load_checkpoint(&dir).unwrap();
    let after: Vec<Vec<f32>> = engine
        .params()
        .iter()
        .map(|l| l.to_vec::<f32>().unwrap())
        .collect();
    assert_eq!(before.len(), after.len());
    for (b, a) in before.iter().zip(&after) {
        assert_eq!(b, a, "checkpoint roundtrip changed parameters");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn deterministic_training_from_fixed_init() {
    // Two fresh engines over the same artifact + same data must produce
    // identical losses (PJRT CPU is deterministic; the seeded init is in
    // the artifact).
    let Some(m) = manifest() else { return };
    let spec = m.variant("tt_L2").unwrap();
    if load_engine(spec).is_none() {
        return;
    }
    let cfg = spec.config.clone();
    let data = Dataset::synth(&cfg, 5, 4);

    let mut run = || -> Vec<f32> {
        let mut engine = Engine::load(spec).unwrap();
        let mut losses = Vec::new();
        for ex in &data.examples {
            let out = engine
                .train_step(&ex.tokens, &[ex.intent], &ex.slots, 4e-3)
                .unwrap();
            losses.push(out.loss);
        }
        losses
    };
    assert_eq!(run(), run());
}
