//! Deterministic data-parallel training suite — all runnable with no
//! artifacts:
//!
//! * R=1 is **bitwise identical** to the plain `NativeTrainModel`
//!   trainer over a 24-step Adam trajectory (losses and every stored
//!   parameter),
//! * the same replica count re-run from the same seed is bitwise
//!   reproducible (the determinism contract: thread completion order
//!   never reaches the reduction),
//! * cross-R trajectories (R = 1 vs 2 vs 4) agree within float
//!   tolerance — same math, different summation grouping,
//! * the fixed-order reduction is a property of replica *indices*, not
//!   arrival order: permuting real model gradient shards changes
//!   nothing, bitwise,
//! * a checkpoint saved mid-epoch under R=2 resumes onto the exact
//!   trajectory of the uninterrupted run,
//! * optimizer state is never double-charged: followers hold zero
//!   moment slots at every R.

use tt_trainer::config::ModelConfig;
use tt_trainer::coordinator::TrainBackend;
use tt_trainer::data::Dataset;
use tt_trainer::engine::ParamMap;
use tt_trainer::optim::{OptimConfig, OptimKind};
use tt_trainer::replica::{allreduce_fixed_order, validate_replica_batch, ReplicaGroup};
use tt_trainer::train::NativeTrainer;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        n_layers: 1,
        d_hid: 48,
        n_heads: 4,
        seq_len: 8,
        batch: 4,
        vocab: 27,
        n_intents: 5,
        n_slots: 7,
        tt_m: vec![4, 4, 3],
        tt_n: vec![3, 4, 4],
        tt_rank: 3,
        ttm_vocab_modes: vec![3, 3, 3],
        ttm_hid_modes: vec![4, 4, 3],
        ttm_rank: 4,
        pad_id: 0,
        cls_id: 1,
        unk_id: 2,
    }
}

/// One fixed global batch of `b` synthetic examples, flattened to the
/// `(tokens, intents, slots)` layout every backend consumes.
fn batch(cfg: &ModelConfig, b: usize) -> (Vec<i32>, Vec<i32>, Vec<i32>) {
    let data = Dataset::synth(cfg, 9, b.max(8));
    let ex = &data.examples[..b];
    (
        ex.iter().flat_map(|e| e.tokens.clone()).collect(),
        ex.iter().map(|e| e.intent).collect(),
        ex.iter().flat_map(|e| e.slots.clone()).collect(),
    )
}

fn adam() -> OptimConfig {
    OptimConfig { kind: OptimKind::Adam, batch_size: 4, ..Default::default() }
}

/// Bitwise parameter-map equality: `to_bits` on every scalar, so -0.0
/// vs 0.0 and NaN payloads cannot hide behind `==`.
fn assert_params_bitwise_eq(a: &ParamMap, b: &ParamMap, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: param count");
    for ((na, (sa, va)), (nb, (sb, vb))) in a.iter().zip(b.iter()) {
        assert_eq!(na, nb, "{what}: param name order");
        assert_eq!(sa, sb, "{what}: shape of {na}");
        assert_eq!(va.len(), vb.len(), "{what}: length of {na}");
        for (i, (x, y)) in va.iter().zip(vb.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: {na}[{i}]: {x} vs {y}");
        }
    }
}

/// Run `steps` Adam steps of the same fixed batch through any backend,
/// returning the per-step losses.
fn run_steps<B: TrainBackend>(backend: &mut B, steps: usize) -> Vec<f32> {
    let cfg = backend.config().clone();
    let (tokens, intents, slots) = batch(&cfg, 4);
    (0..steps)
        .map(|_| {
            backend
                .train_step(&tokens, &intents, &slots, OptimKind::Adam.default_lr())
                .expect("train step")
                .loss
        })
        .collect()
}

#[test]
fn r1_is_bitwise_the_plain_trainer_over_24_adam_steps() {
    let cfg = tiny_cfg();
    let mut plain = NativeTrainer::random_init(&cfg, 42).unwrap().with_optim(adam());
    let lead = NativeTrainer::random_init(&cfg, 42).unwrap().with_optim(adam());
    let mut group = ReplicaGroup::new(lead, 1).unwrap();
    assert_eq!(group.replicas(), 1);

    let plain_losses = run_steps(&mut plain, 24);
    let group_losses = run_steps(&mut group, 24);
    for (i, (p, g)) in plain_losses.iter().zip(group_losses.iter()).enumerate() {
        assert_eq!(p.to_bits(), g.to_bits(), "step {i}: loss {p} vs {g}");
    }
    assert_params_bitwise_eq(
        &plain.model.to_params(),
        &group.lead().model.to_params(),
        "R=1 vs plain after 24 steps",
    );
}

#[test]
fn same_replica_count_reruns_are_bitwise_reproducible() {
    let cfg = tiny_cfg();
    for r in [2usize, 4] {
        let mut runs = Vec::new();
        for _ in 0..2 {
            let lead = NativeTrainer::random_init(&cfg, 42).unwrap().with_optim(adam());
            let mut group = ReplicaGroup::new(lead, r).unwrap();
            let losses = run_steps(&mut group, 24);
            runs.push((losses, group.lead().model.to_params()));
        }
        let (l0, p0) = &runs[0];
        let (l1, p1) = &runs[1];
        for (i, (a, b)) in l0.iter().zip(l1.iter()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "R={r} step {i}: loss {a} vs {b}");
        }
        assert_params_bitwise_eq(p0, p1, &format!("R={r} rerun"));
    }
}

#[test]
fn cross_replica_trajectories_agree_within_tolerance() {
    let cfg = tiny_cfg();
    let mut trajectories = Vec::new();
    for r in [1usize, 2, 4] {
        let lead = NativeTrainer::random_init(&cfg, 42).unwrap().with_optim(adam());
        let mut group = ReplicaGroup::new(lead, r).unwrap();
        trajectories.push(run_steps(&mut group, 24));
    }
    let base = &trajectories[0];
    for (ri, traj) in trajectories.iter().enumerate().skip(1) {
        // Step 0 runs on identical parameters: the only difference is
        // the grouping of the per-example loss mean, so the losses
        // agree to float-rounding precision.
        let first = (traj[0] - base[0]).abs();
        assert!(first < 1e-5, "R idx {ri} step 0 diverged by {first}");
        // Summation-order rounding compounds through Adam; the
        // trajectories must stay in lockstep, not bitwise.
        for (i, (a, b)) in base.iter().zip(traj.iter()).enumerate() {
            let tol = 1e-4 + 2e-3 * i as f32;
            assert!(
                (a - b).abs() < tol,
                "R idx {ri} step {i}: loss {a} vs {b} (tol {tol})"
            );
        }
    }
}

#[test]
fn fixed_order_reduction_ignores_arrival_order_of_real_grads() {
    let cfg = tiny_cfg();
    let model = NativeTrainer::random_init(&cfg, 7).unwrap().with_optim(adam()).model;
    let (tokens, intents, slots) = batch(&cfg, 4);
    let s = cfg.seq_len;
    // Two strided shards of the global batch (examples {0,2} and {1,3}).
    let shard = |rows: &[usize]| {
        let t: Vec<i32> = rows.iter().flat_map(|&e| tokens[e * s..(e + 1) * s].to_vec()).collect();
        let i: Vec<i32> = rows.iter().map(|&e| intents[e]).collect();
        let sl: Vec<i32> = rows.iter().flat_map(|&e| slots[e * s..(e + 1) * s].to_vec()).collect();
        let (_, grads, _) = model.forward_backward(&t, &i, &sl).unwrap();
        (i.len(), grads)
    };
    let (b0, g0) = shard(&[0, 2]);
    let (b1, g1) = shard(&[1, 3]);

    let fwd = allreduce_fixed_order(vec![(0, b0, g0.clone()), (1, b1, g1.clone())]).unwrap();
    let rev = allreduce_fixed_order(vec![(1, b1, g1), (0, b0, g0)]).unwrap();
    assert_eq!(fwd.len(), rev.len());
    for ((na, va), (nb, vb)) in fwd.iter().zip(rev.iter()) {
        assert_eq!(na, nb);
        for (i, (x, y)) in va.iter().zip(vb.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{na}[{i}] depends on arrival order");
        }
    }
}

#[test]
fn checkpoint_save_resume_mid_epoch_under_r2() {
    let cfg = tiny_cfg();
    let dir = std::env::temp_dir().join(format!("replica_ckpt_{}", std::process::id()));

    // Uninterrupted run: 16 steps.
    let lead = NativeTrainer::random_init(&cfg, 42).unwrap().with_optim(adam());
    let mut full = ReplicaGroup::new(lead, 2).unwrap();
    let full_losses = run_steps(&mut full, 16);

    // Interrupted run: 8 steps, checkpoint, resume into a *fresh* group
    // (different init seed — everything must come from the checkpoint,
    // including the Adam moments and the followers' re-synced params).
    let lead = NativeTrainer::random_init(&cfg, 42).unwrap().with_optim(adam());
    let mut first = ReplicaGroup::new(lead, 2).unwrap();
    let first_losses = run_steps(&mut first, 8);
    first.save_checkpoint(&dir).unwrap();

    let lead = NativeTrainer::random_init(&cfg, 1234).unwrap().with_optim(adam());
    let mut resumed = ReplicaGroup::new(lead, 2).unwrap();
    resumed.load_checkpoint(&dir).unwrap();
    let resumed_losses = run_steps(&mut resumed, 8);

    for (i, (a, b)) in full_losses[..8].iter().zip(first_losses.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "pre-checkpoint step {i}");
    }
    for (i, (a, b)) in full_losses[8..].iter().zip(resumed_losses.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "post-resume step {i}: loss {a} vs {b}");
    }
    assert_params_bitwise_eq(
        &full.lead().model.to_params(),
        &resumed.lead().model.to_params(),
        "resumed vs uninterrupted after 16 steps",
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn replica_count_above_global_batch_is_rejected_loudly() {
    // Regression: `--replicas R` with a global batch below R used to be
    // accepted silently — the partial-tail drop rule then discarded
    // every batch and the run "trained" zero steps.  The pairing must
    // be rejected at validation time, before any model is built.
    for (replicas, batch) in [(1usize, 1usize), (2, 2), (2, 7), (4, 4), (8, 64)] {
        validate_replica_batch(replicas, batch)
            .unwrap_or_else(|e| panic!("R={replicas} batch={batch} wrongly rejected: {e}"));
    }
    for (replicas, batch) in [(2usize, 1usize), (4, 3), (8, 4), (64, 8)] {
        let err = validate_replica_batch(replicas, batch)
            .expect_err(&format!("R={replicas} batch={batch} wrongly accepted"));
        let msg = err.to_string();
        assert!(msg.contains("zero steps"), "unhelpful error: {msg}");
        assert!(msg.contains(&replicas.to_string()) && msg.contains(&batch.to_string()));
    }
    // Zero replicas makes no sense at any batch size.
    assert!(validate_replica_batch(0, 16).is_err());
    // The same rule is what the scheduler consults mid-run.
    let lead = NativeTrainer::random_init(&tiny_cfg(), 42).unwrap().with_optim(adam());
    let group = ReplicaGroup::new(lead, 2).unwrap();
    assert!(group.supports_batch(2) && !group.supports_batch(1));
}

#[test]
fn optimizer_state_is_never_double_charged() {
    let cfg = tiny_cfg();
    let mut plain = NativeTrainer::random_init(&cfg, 42).unwrap().with_optim(adam());
    run_steps(&mut plain, 4);
    let plain_bytes = plain.model.optim.allocated_state_bytes();
    assert!(plain_bytes > 0, "Adam must allocate moments");

    for r in [1usize, 2, 4] {
        let lead = NativeTrainer::random_init(&cfg, 42).unwrap().with_optim(adam());
        let mut group = ReplicaGroup::new(lead, r).unwrap();
        run_steps(&mut group, 4);
        // The group's whole state is the lead's state — followers never
        // step and never allocate a single moment slot.
        assert_eq!(group.follower_state_elems(), 0, "R={r}: follower allocated moments");
        assert_eq!(
            group.allocated_state_bytes(),
            group.lead().model.optim.allocated_state_bytes(),
            "R={r}: group state must be exactly the lead's"
        );
        assert_eq!(
            group.allocated_state_bytes(),
            plain_bytes,
            "R={r}: replication changed the optimizer-state footprint"
        );
    }
}
