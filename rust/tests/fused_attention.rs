//! Kernel parity tests for the fused compute path: the fused QKV
//! TT-linear must match three separate TT forwards (and its backward
//! must match finite differences), and batched attention must match the
//! per-example reference on ragged pad masks — forward and VJP.  These
//! are the acceptance gates of the fused/batched schedule and run in CI
//! as a named step.

use tt_trainer::costmodel::LinearShape;
use tt_trainer::tensor::{ops, ContractionStats, Tensor};
use tt_trainer::train::blocks;
use tt_trainer::train::{
    backward_qkv_fused, forward_qkv_fused, qkv_input_cores_shared, TTLinear,
};
use tt_trainer::util::rng::SplitMix64;

/// Paper-shaped (but tiny) Q/K/V triplet with tied input-side cores.
fn triplet(rng: &mut SplitMix64) -> (TTLinear, TTLinear, TTLinear) {
    let wq = TTLinear::randn(&[4, 3], &[3, 4], 3, 0.5, rng);
    let mut wk = TTLinear::randn(&[4, 3], &[3, 4], 3, 0.5, rng);
    let mut wv = TTLinear::randn(&[4, 3], &[3, 4], 3, 0.5, rng);
    let src = wq.tt().into_owned();
    let d = src.d();
    for w in [&mut wk, &mut wv] {
        w.update_tt(|tt| {
            for c in d..2 * d {
                tt.cores[c] = src.cores[c].clone();
            }
        });
    }
    assert!(qkv_input_cores_shared(&wq, &wk, &wv));
    (wq, wk, wv)
}

#[test]
fn fused_qkv_forward_matches_three_separate_forwards() {
    let mut rng = SplitMix64::new(101);
    let (wq, wk, wv) = triplet(&mut rng);
    let k_dim = 9usize;
    let x = Tensor::randn(&[k_dim, 12], 1.0, &mut rng);
    let mut fused = ContractionStats::default();
    let ([yq, yk, yv], _) = forward_qkv_fused(&wq, &wk, &wv, &x, &mut fused).unwrap();
    let mut sep = ContractionStats::default();
    for (w, y) in [(&wq, &yq), (&wk, &yk), (&wv, &yv)] {
        let (y_ref, _) = w.forward(&x, &mut sep).unwrap();
        assert!(
            y.max_abs_diff(&y_ref) <= 1e-6,
            "fused projection diverges: {}",
            y.max_abs_diff(&y_ref)
        );
    }
    // Acceptance: fewer contraction MULs than 3x separate forwards,
    // matching the new cost-model expression.
    assert!(fused.muls < sep.muls);
    let shape = LinearShape {
        m_modes: wq.tt().m_modes.clone(),
        n_modes: wq.tt().n_modes.clone(),
        ranks: wq.tt().ranks.clone(),
    };
    assert_eq!(fused.muls, shape.btt_fwd_qkv_muls(k_dim as u64));
    assert_eq!(sep.muls, 3 * shape.btt_muls(k_dim as u64));
    assert_eq!(fused.stored_intermediate_elems, shape.btt_qkv_memory(k_dim as u64));
}

#[test]
fn fused_qkv_gradients_match_finite_differences() {
    // loss = <probe_q, Q> + <probe_k, K> + <probe_v, V>: central
    // differences on every core entry (tied input cores perturbed in
    // lockstep, matching the tied parameterization's chain rule) and
    // every bias entry must match the fused backward.
    let mut rng = SplitMix64::new(102);
    let (wq, wk, wv) = triplet(&mut rng);
    let d = wq.tt().d();
    let mut lins = [wq, wk, wv];
    let k_dim = 4usize;
    let x = Tensor::randn(&[k_dim, 12], 1.0, &mut rng);
    let probes: Vec<Tensor> = (0..3).map(|_| Tensor::randn(&[k_dim, 12], 1.0, &mut rng)).collect();

    let loss = |lins: &[TTLinear; 3], probes: &[Tensor]| -> f32 {
        let mut stats = ContractionStats::default();
        let (ys, _) =
            forward_qkv_fused(&lins[0], &lins[1], &lins[2], &x, &mut stats).unwrap();
        ys.iter()
            .zip(probes)
            .map(|(y, p)| y.data.iter().zip(&p.data).map(|(a, b)| a * b).sum::<f32>())
            .sum()
    };

    let mut stats = ContractionStats::default();
    let (_, cache) = forward_qkv_fused(&lins[0], &lins[1], &lins[2], &x, &mut stats).unwrap();
    let (_, grads) = backward_qkv_fused(
        &lins[0], &lins[1], &lins[2], &probes[0], &probes[1], &probes[2], &cache, &mut stats,
    )
    .unwrap();

    let eps = 1e-2f32;
    // Per-projection output-side cores.
    for p in 0..3 {
        for k in 0..d {
            for idx in 0..lins[p].tt().cores[k].numel() {
                let orig = lins[p].tt().cores[k].data[idx];
                lins[p].update_tt(|tt| tt.cores[k].data[idx] = orig + eps);
                let up = loss(&lins, &probes);
                lins[p].update_tt(|tt| tt.cores[k].data[idx] = orig - eps);
                let dn = loss(&lins, &probes);
                lins[p].update_tt(|tt| tt.cores[k].data[idx] = orig);
                let fd = (up - dn) / (2.0 * eps);
                let an = grads.m_cores[p][k].data[idx];
                assert!(
                    (fd - an).abs() < 1e-3 * (1.0 + an.abs()),
                    "proj {p} m-core {k}[{idx}]: fd {fd} vs analytic {an}"
                );
            }
        }
    }
    // Tied input-side cores: perturb all three copies together (the
    // tied parameterization's derivative is the summed gradient).
    for k in 0..d {
        let c = d + k;
        for idx in 0..lins[0].tt().cores[c].numel() {
            let orig = lins[0].tt().cores[c].data[idx];
            let set = |lins: &mut [TTLinear; 3], v: f32| {
                for l in lins.iter_mut() {
                    l.update_tt(|tt| tt.cores[c].data[idx] = v);
                }
            };
            set(&mut lins, orig + eps);
            let up = loss(&lins, &probes);
            set(&mut lins, orig - eps);
            let dn = loss(&lins, &probes);
            set(&mut lins, orig);
            let fd = (up - dn) / (2.0 * eps);
            let an = grads.n_cores[k].data[idx];
            assert!(
                (fd - an).abs() < 1e-3 * (1.0 + an.abs()),
                "shared n-core {c}[{idx}]: fd {fd} vs analytic {an}"
            );
        }
    }
    // Biases.
    for (p, g) in grads.bias.iter().enumerate() {
        // d(loss)/d(bias_j) = column sum of the probe.
        for (j, &an) in g.iter().enumerate() {
            let want: f32 = (0..k_dim).map(|i| probes[p].at2(i, j)).sum();
            assert!((an - want).abs() < 1e-4, "proj {p} bias[{j}]");
        }
    }
}

/// Independent naive attention reference: explicit triple loops and an
/// exclusion-mask softmax, sharing **no** code with the `bmm`/packing
/// kernels under test — a shared-kernel regression cannot cancel out of
/// this comparison.
fn naive_attention(q: &Tensor, k: &Tensor, v: &Tensor, mask: &[f32], n_heads: usize) -> Tensor {
    let (s, h) = (q.shape[0], q.shape[1]);
    let dh = h / n_heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut ctx = Tensor::zeros(&[s, h]);
    for head in 0..n_heads {
        for i in 0..s {
            // scores for query i against every key, masked softmax in f64.
            let mut row = vec![0.0f64; s];
            for j in 0..s {
                let mut acc = 0.0f64;
                for t in 0..dh {
                    acc += q.data[i * h + head * dh + t] as f64
                        * k.data[j * h + head * dh + t] as f64;
                }
                row[j] = acc * scale as f64;
            }
            let maxv = row
                .iter()
                .zip(mask)
                .filter(|(_, &m)| m > 0.5)
                .map(|(&x, _)| x)
                .fold(f64::NEG_INFINITY, f64::max);
            let mut sum = 0.0f64;
            let mut probs = vec![0.0f64; s];
            for j in 0..s {
                if mask[j] > 0.5 {
                    probs[j] = (row[j] - maxv).exp();
                    sum += probs[j];
                }
            }
            for t in 0..dh {
                let mut acc = 0.0f64;
                for j in 0..s {
                    acc += probs[j] / sum * v.data[j * h + head * dh + t] as f64;
                }
                ctx.data[i * h + head * dh + t] = acc as f32;
            }
        }
    }
    ctx
}

#[test]
fn batched_attention_matches_independent_naive_reference() {
    // The batched kernel vs a from-scratch f64 implementation (not the
    // B = 1 view of itself): catches regressions in the shared
    // bias/softmax/bmm path that a self-comparison would cancel out.
    let mut rng = SplitMix64::new(104);
    let (b, s, h, heads) = (2usize, 6usize, 8usize, 2usize);
    let q = Tensor::randn(&[b * s, h], 0.8, &mut rng);
    let k = Tensor::randn(&[b * s, h], 0.8, &mut rng);
    let v = Tensor::randn(&[b * s, h], 0.8, &mut rng);
    let mask = [1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0];
    let bias = ops::attention_bias_from_mask(&mask);
    let (ctx, _) = ops::multi_head_attention_batched(&q, &k, &v, &bias, heads, b).unwrap();
    for e in 0..b {
        let slice = |t: &Tensor| {
            Tensor::from_vec(t.data[e * s * h..(e + 1) * s * h].to_vec(), &[s, h]).unwrap()
        };
        let want = naive_attention(
            &slice(&q),
            &slice(&k),
            &slice(&v),
            &mask[e * s..(e + 1) * s],
            heads,
        );
        let got = slice(&ctx);
        assert!(
            got.max_abs_diff(&want) < 1e-5,
            "example {e}: batched attention diverges from naive f64 reference by {}",
            got.max_abs_diff(&want)
        );
    }
}

#[test]
fn batched_attention_matches_per_example_reference_on_ragged_masks() {
    let mut rng = SplitMix64::new(103);
    let (b, s, h, heads) = (3usize, 7usize, 12usize, 3usize);
    let q = Tensor::randn(&[b * s, h], 0.8, &mut rng);
    let k = Tensor::randn(&[b * s, h], 0.8, &mut rng);
    let v = Tensor::randn(&[b * s, h], 0.8, &mut rng);
    // Ragged pads: 2, 0 and 5 pad positions respectively.
    let mut mask = vec![1.0f32; b * s];
    for &p in &[5usize, 6, 16, 17, 18, 19, 20] {
        mask[p] = 0.0;
    }
    let bias = ops::attention_bias_from_mask(&mask);
    let (ctx, probs) = ops::multi_head_attention_batched(&q, &k, &v, &bias, heads, b).unwrap();
    let d_ctx = Tensor::randn(&[b * s, h], 1.0, &mut rng);
    let (dq, dk, dv) =
        blocks::multi_head_attention_vjp_batched(&q, &k, &v, &probs, &d_ctx, heads, b).unwrap();

    for e in 0..b {
        let slice = |t: &Tensor| {
            Tensor::from_vec(t.data[e * s * h..(e + 1) * s * h].to_vec(), &[s, h]).unwrap()
        };
        let (qe, ke, ve) = (slice(&q), slice(&k), slice(&v));
        let me = &mask[e * s..(e + 1) * s];
        let (ctx_e, probs_e) = ops::multi_head_attention(&qe, &ke, &ve, me, heads).unwrap();
        assert_eq!(
            &ctx.data[e * s * h..(e + 1) * s * h],
            &ctx_e.data[..],
            "example {e}: batched ctx != per-example reference"
        );
        let (dqe, dke, dve) =
            blocks::multi_head_attention_vjp(&qe, &ke, &ve, &probs_e, &slice(&d_ctx), heads)
                .unwrap();
        for (name, got, want) in [("dq", &dq, &dqe), ("dk", &dk, &dke), ("dv", &dv, &dve)] {
            assert_eq!(
                &got.data[e * s * h..(e + 1) * s * h],
                &want.data[..],
                "example {e}: batched {name} != per-example reference"
            );
        }
        // Pad positions receive exactly zero dK/dV (no key/value grad
        // can flow through a zero-probability column).
        for (p, &m) in me.iter().enumerate() {
            if m == 0.0 {
                for j in 0..h {
                    assert_eq!(dk.data[(e * s + p) * h + j], 0.0);
                    assert_eq!(dv.data[(e * s + p) * h + j], 0.0);
                }
            }
        }
    }
}
