//! Whole-stack cross-validation: the rust-native inference engine
//! ([`tt_trainer::inference`]) must reproduce the PJRT/HLO path's logits
//! on the same parameters.  Needs the `pjrt` feature; each test skips
//! itself when `make artifacts` has not been run.
//!
//! This closes the loop across every layer of the system:
//!   Pallas kernels -> JAX model -> HLO text -> PJRT execution
//! vs
//!   TT/TTM rust tensor algebra -> native forward pass.
#![cfg(feature = "pjrt")]

use tt_trainer::data::Dataset;
use tt_trainer::inference::{params_from_engine, NativeModel};
use tt_trainer::runtime::{Engine, Manifest};

fn manifest() -> Option<Manifest> {
    match Manifest::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")) {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("skipping: artifacts/ not present (run `make artifacts`)");
            None
        }
    }
}

/// Load an engine, or skip gracefully — the `xla` dependency may be the
/// vendored type-check stub, whose PJRT client never comes up.
fn load_engine(spec: &tt_trainer::runtime::VariantSpec) -> Option<Engine> {
    match Engine::load(spec) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping: PJRT unavailable ({e})");
            None
        }
    }
}

fn max_rel_err(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs() / (1.0 + x.abs().max(y.abs())))
        .fold(0.0, f32::max)
}

#[test]
fn native_forward_matches_pjrt_eval() {
    let Some(m) = manifest() else { return };
    let spec = m.variant("tt_L2").unwrap();
    let Some(mut engine) = load_engine(spec) else { return };
    let cfg = spec.config.clone();
    let data = Dataset::synth(&cfg, 1234, 6);

    // Train a couple of steps so the comparison is not at the (symmetric)
    // init point.
    for ex in data.examples.iter().take(2) {
        engine
            .train_step(&ex.tokens, &[ex.intent], &ex.slots, 4e-3)
            .unwrap();
    }

    let native = NativeModel::from_params(&cfg, &params_from_engine(&engine).unwrap()).unwrap();

    for ex in &data.examples {
        let (il_pjrt, sl_pjrt) = engine.eval(&ex.tokens).unwrap();
        let (il_native, sl_native) = native.forward(&ex.tokens).unwrap();
        let e_i = max_rel_err(&il_pjrt, &il_native);
        let e_s = max_rel_err(&sl_pjrt, &sl_native);
        assert!(e_i < 2e-3, "intent logits diverge: rel err {e_i}");
        assert!(e_s < 2e-3, "slot logits diverge: rel err {e_s}");
    }
}

#[test]
fn native_predictions_match_pjrt_argmax() {
    let Some(m) = manifest() else { return };
    let spec = m.variant("tt_L2").unwrap();
    let Some(engine) = load_engine(spec) else { return };
    let cfg = spec.config.clone();
    let native = NativeModel::from_params(&cfg, &params_from_engine(&engine).unwrap()).unwrap();
    let data = Dataset::synth(&cfg, 77, 10);
    let mut agree = 0;
    for ex in &data.examples {
        let (il, _) = engine.eval(&ex.tokens).unwrap();
        let pjrt_intent = il
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let (native_intent, _) = native.predict(&ex.tokens).unwrap();
        if pjrt_intent == native_intent {
            agree += 1;
        }
    }
    assert!(agree >= 9, "argmax agreement {agree}/10");
}
