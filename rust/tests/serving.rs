//! Serving integration suite — the single-source-of-truth and
//! determinism contracts of the shared engine + scheduler stack:
//!
//! * **Old-vs-new parity**: `inference::NativeModel` (now an alias of
//!   the shared engine) reproduces the training model's `eval` logits
//!   **bitwise** on the synthetic ATIS test split — `inference/` no
//!   longer carries its own encoder forward, and nothing drifted in
//!   the move.
//! * **Batch-composition invariance**: a request's intent/slot logits
//!   are bitwise identical whether it is served alone, in a full
//!   bucket, or interleaved with requests of other lengths — directly
//!   through `forward_len` and through a live `serve::Server`, across
//!   `Precision` f32/bf16 and both `ComputePath`s.
//! * **Admission control** through the public API: explicit
//!   `QueueFull` rejects at capacity, accepted requests drained at
//!   shutdown, rejected work servable by a fresh server.

use std::sync::Arc;
use std::time::Duration;
use tt_trainer::config::ModelConfig;
use tt_trainer::coordinator::metrics::argmax;
use tt_trainer::coordinator::TrainBackend;
use tt_trainer::data::Dataset;
use tt_trainer::engine::{ComputePath, NativeEngine};
use tt_trainer::inference::NativeModel;
use tt_trainer::serve::{BucketStats, ServeConfig, Server, SubmitError};
use tt_trainer::tensor::Precision;
use tt_trainer::train::NativeTrainer;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        n_layers: 1,
        d_hid: 48,
        n_heads: 4,
        seq_len: 8,
        batch: 1,
        vocab: 27,
        n_intents: 5,
        n_slots: 7,
        tt_m: vec![4, 4, 3],
        tt_n: vec![3, 4, 4],
        tt_rank: 3,
        ttm_vocab_modes: vec![3, 3, 3],
        ttm_hid_modes: vec![4, 4, 3],
        ttm_rank: 4,
        pad_id: 0,
        cls_id: 1,
        unk_id: 2,
    }
}

/// Requests of deliberately mixed effective lengths: three land in the
/// 4-bucket, two in the 8-bucket (tiny `seq_len` 8, bucket 4).
fn mixed_requests() -> Vec<Vec<i32>> {
    vec![
        vec![1, 5],
        vec![1, 9, 13],
        vec![1, 7, 3, 21],
        vec![1, 5, 9, 13, 17],
        vec![1, 3, 5, 7, 9, 11, 13, 15],
    ]
}

fn pad_to(tokens: &[i32], len: usize, pad: i32) -> Vec<i32> {
    let mut v = tokens.to_vec();
    v.resize(len, pad);
    v
}

/// A request's served-alone logits at its bucket length.
fn reference(
    engine: &NativeEngine,
    serve_cfg: &ServeConfig,
    req: &[i32],
) -> (Vec<f32>, Vec<f32>, usize) {
    let cfg = &engine.cfg;
    let bl = serve_cfg.bucket_len(req.len(), cfg.seq_len);
    let (il, sl) = engine.forward_len(&pad_to(req, bl, cfg.pad_id), bl).unwrap();
    (il, sl, bl)
}

/// The grid the determinism guarantee spans.
fn engine_grid(cfg: &ModelConfig, seed: u64) -> Vec<(NativeEngine, &'static str)> {
    let params = NativeTrainer::random_init(cfg, seed).unwrap().model.to_params();
    let mut out = Vec::new();
    for (path, pname) in [(ComputePath::fused(), "fused"), (ComputePath::looped(), "looped")] {
        for prec in [Precision::F32, Precision::Bf16] {
            let engine = NativeEngine::from_params_with(cfg, &params, path, prec).unwrap();
            out.push((engine, pname));
        }
    }
    out
}

#[test]
fn inference_alias_matches_training_eval_on_atis_split() {
    // The tentpole's parity pin: the deduplicated forward behind the
    // historical `inference::NativeModel` name reproduces the training
    // model's eval logits bitwise on the ATIS test split, away from
    // the init point.
    let mut cfg = ModelConfig::paper(1);
    cfg.seq_len = 16; // shorter sequences: faster test, same paths
    let mut trainer = NativeTrainer::random_init(&cfg, 11).unwrap();
    let (train, test) = Dataset::paper_splits(&cfg, 11);
    for ex in train.examples.iter().take(3) {
        trainer.train_step(&ex.tokens, &[ex.intent], &ex.slots, 4e-3).unwrap();
    }
    let model = NativeModel::from_params(&cfg, &trainer.model.to_params()).unwrap();
    for ex in test.examples.iter().take(16) {
        let (il_train, sl_train) = trainer.model.eval(&ex.tokens).unwrap();
        let (il, sl) = model.forward(&ex.tokens).unwrap();
        assert_eq!(il, il_train, "intent logits drifted from the training forward");
        assert_eq!(sl, sl_train, "slot logits drifted from the training forward");
        let (intent, slots) = model.predict(&ex.tokens).unwrap();
        assert_eq!(intent, argmax(&il_train));
        assert_eq!(slots.len(), cfg.seq_len);
    }
}

#[test]
fn composition_invariance_direct_forward() {
    // Same-bucket requests batched together must reproduce each
    // request's served-alone logits bitwise — the row-independence the
    // scheduler's determinism guarantee rests on.  Checked across both
    // compute paths and f32/bf16.
    let cfg = tiny_cfg();
    let serve_cfg = ServeConfig { bucket: 4, ..ServeConfig::default() };
    let (ni, ns, pad) = (cfg.n_intents, cfg.n_slots, cfg.pad_id);
    for (engine, pname) in engine_grid(&cfg, 41) {
        let prec = engine.precision.name();
        let reqs = mixed_requests();
        let refs: Vec<_> = reqs.iter().map(|r| reference(&engine, &serve_cfg, r)).collect();
        // Full 4-bucket: requests 0..3 share bucket length 4.
        let bl = refs[0].2;
        assert!(refs[..3].iter().all(|r| r.2 == bl));
        let batch: Vec<i32> =
            reqs[..3].iter().flat_map(|r| pad_to(r, bl, pad)).collect();
        let (il, sl) = engine.forward_len(&batch, bl).unwrap();
        for (i, (il_ref, sl_ref, _)) in refs[..3].iter().enumerate() {
            assert_eq!(
                &il[i * ni..(i + 1) * ni],
                &il_ref[..],
                "[{pname}/{prec}] intent logits differ alone vs full bucket (req {i})"
            );
            assert_eq!(
                &sl[i * bl * ns..(i + 1) * bl * ns],
                &sl_ref[..],
                "[{pname}/{prec}] slot logits differ alone vs full bucket (req {i})"
            );
        }
        // 8-bucket pair, in both orders (batch position must not matter).
        let bl8 = refs[3].2;
        assert_eq!(bl8, refs[4].2);
        for order in [[3usize, 4], [4, 3]] {
            let batch: Vec<i32> =
                order.iter().flat_map(|&i| pad_to(&reqs[i], bl8, pad)).collect();
            let (il, sl) = engine.forward_len(&batch, bl8).unwrap();
            for (row, &i) in order.iter().enumerate() {
                assert_eq!(&il[row * ni..(row + 1) * ni], &refs[i].0[..]);
                assert_eq!(&sl[row * bl8 * ns..(row + 1) * bl8 * ns], &refs[i].1[..]);
            }
        }
    }
}

#[test]
fn composition_invariance_through_live_server() {
    // The same guarantee end to end: requests submitted interleaved by
    // length to a live server, coalesced into full per-bucket batches
    // at shutdown drain, answer with bitwise the served-alone logits.
    let cfg = tiny_cfg();
    for (engine, pname) in engine_grid(&cfg, 43) {
        let prec = engine.precision.name();
        let serve_cfg = ServeConfig {
            max_batch: 8,
            max_wait: Duration::from_secs(3600), // fire only at drain
            queue_cap: 64,
            bucket: 4,
        };
        let engine = Arc::new(engine);
        let reqs = mixed_requests();
        let refs: Vec<_> =
            reqs.iter().map(|r| reference(&engine, &serve_cfg, r)).collect();
        let server = Server::start(Arc::clone(&engine), serve_cfg).unwrap();
        let handle = server.handle();
        // Interleave the 8-bucket and 4-bucket requests on submission.
        let order = [3usize, 0, 4, 1, 2];
        let pending: Vec<_> =
            order.iter().map(|&i| (i, handle.submit(&reqs[i]).unwrap())).collect();
        let stats_thread = std::thread::spawn(move || server.shutdown());
        let ns = cfg.n_slots;
        for (i, p) in pending {
            let resp = p.wait().unwrap();
            let (il_ref, sl_ref, _) = &refs[i];
            let eff = reqs[i].len();
            assert_eq!(
                resp.intent_logits, *il_ref,
                "[{pname}/{prec}] served intent logits differ from alone (req {i})"
            );
            assert_eq!(
                resp.slot_logits,
                sl_ref[..eff * ns].to_vec(),
                "[{pname}/{prec}] served slot logits differ from alone (req {i})"
            );
            // Drain coalesces whole buckets: 3 requests in the
            // 4-bucket, 2 in the 8-bucket.
            let expect = if i <= 2 { 3 } else { 2 };
            assert_eq!(resp.batch_size, expect, "[{pname}/{prec}] bucket did not coalesce");
        }
        let stats = stats_thread.join().unwrap();
        assert_eq!(stats.served, 5);
        assert_eq!(stats.batches, 2);
        // Distribution accounting: all 5 requests queue before the
        // drain (hour-long max_wait, max_batch 8), so the high
        // watermark and the per-bucket split are deterministic.
        assert_eq!(stats.queue_depth_hwm, 5, "[{pname}/{prec}]");
        assert_eq!(
            stats.per_bucket,
            vec![
                BucketStats { bucket_len: 4, served: 3, batches: 1 },
                BucketStats { bucket_len: 8, served: 2, batches: 1 },
            ],
            "[{pname}/{prec}] per-bucket served/batch counts"
        );
        // Latency percentiles over the 5 served requests: finite,
        // positive, monotone p50 <= p95 <= p99.
        assert!(stats.latency_p50_ms.is_finite() && stats.latency_p50_ms > 0.0);
        assert!(stats.latency_p50_ms <= stats.latency_p95_ms);
        assert!(stats.latency_p95_ms <= stats.latency_p99_ms);
    }
}

#[test]
fn admission_control_rejects_then_fresh_server_recovers() {
    let cfg = tiny_cfg();
    let engine = Arc::new(
        NativeEngine::from_params(
            &cfg,
            &NativeTrainer::random_init(&cfg, 47).unwrap().model.to_params(),
        )
        .unwrap(),
    );
    let held = ServeConfig {
        max_batch: 16,
        max_wait: Duration::from_secs(3600),
        queue_cap: 2,
        bucket: 4,
    };
    let server = Server::start(Arc::clone(&engine), held).unwrap();
    let handle = server.handle();
    let a = handle.submit(&[1, 5, 9]).unwrap();
    let b = handle.submit(&[1, 7, 3]).unwrap();
    let rejected_tokens = vec![1, 11, 13];
    match handle.submit(&rejected_tokens) {
        Err(SubmitError::QueueFull { capacity: 2 }) => {}
        other => panic!("expected explicit QueueFull reject, got {other:?}"),
    }
    let stats_thread = std::thread::spawn(move || server.shutdown());
    assert!(a.wait().is_ok(), "accepted request dropped at drain");
    assert!(b.wait().is_ok(), "accepted request dropped at drain");
    let stats = stats_thread.join().unwrap();
    assert_eq!((stats.served, stats.rejected), (2, 1));
    // The rejected work is not poisoned: a fresh server serves it, and
    // the answer matches the engine's direct prediction.
    let server = Server::start(Arc::clone(&engine), ServeConfig::no_batching()).unwrap();
    let resp = server.handle().submit(&rejected_tokens).unwrap().wait().unwrap();
    let (intent, _) = engine.predict(&rejected_tokens).unwrap();
    assert_eq!(resp.intent, intent);
    server.shutdown();
}
