//! Mixed-precision parity suite — all runnable with no artifacts:
//!
//! * the bf16 loss trajectory stays within tolerance of f32 over 24
//!   native training steps (and actually trains),
//! * gradients finite-difference-check through the bf16/f16 rounding
//!   round-trip,
//! * the half-width storage path is bitwise deterministic and halves
//!   the Eq. 21 cache + optimizer-state bytes end to end,
//! * `Precision::F32` through the precision-aware entry points is
//!   bitwise the legacy full-precision path.

use tt_trainer::config::ModelConfig;
use tt_trainer::coordinator::TrainBackend;
use tt_trainer::optim::{OptimConfig, OptimKind};
use tt_trainer::tensor::{ContractionStats, Precision, Tensor};
use tt_trainer::train::{NativeTrainer, TTLinear};
use tt_trainer::util::rng::SplitMix64;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        n_layers: 1,
        d_hid: 48,
        n_heads: 4,
        seq_len: 8,
        batch: 1,
        vocab: 27,
        n_intents: 5,
        n_slots: 7,
        tt_m: vec![4, 4, 3],
        tt_n: vec![3, 4, 4],
        tt_rank: 3,
        ttm_vocab_modes: vec![3, 3, 3],
        ttm_hid_modes: vec![4, 4, 3],
        ttm_rank: 4,
        pad_id: 0,
        cls_id: 1,
        unk_id: 2,
    }
}

/// Two fixed examples at the tiny config (tokens, intents, slots).
fn two_examples() -> (Vec<i32>, Vec<i32>, Vec<i32>) {
    let tokens = vec![
        1, 5, 9, 13, 4, 0, 0, 0, // example 0
        1, 3, 2, 7, 11, 26, 6, 0, // example 1
    ];
    let intents = vec![2, 4];
    let slots = vec![
        0, 1, 2, 3, 1, 0, 0, 0, //
        0, 2, 2, 4, 5, 6, 1, 0, //
    ];
    (tokens, intents, slots)
}

/// Run 24 batched Adam steps at the given storage precision and return
/// the per-step losses.
fn adam_trajectory(prec: Precision) -> Vec<f32> {
    let (tokens, intents, slots) = two_examples();
    let mut t = NativeTrainer::random_init(&tiny_cfg(), 21)
        .unwrap()
        .with_optim(OptimConfig { kind: OptimKind::Adam, precision: prec, ..Default::default() });
    (0..24)
        .map(|_| t.train_step(&tokens, &intents, &slots, 1e-2).unwrap().loss)
        .collect()
}

#[test]
fn bf16_loss_trajectory_tracks_f32_within_tolerance() {
    // Acceptance: >= 20 native training steps, bf16 within tolerance of
    // f32.  Half-precision storage perturbs every step by ~2^-8
    // relative, so the trajectories drift but must stay close, and both
    // must actually train.
    let f32_losses = adam_trajectory(Precision::F32);
    let bf16_losses = adam_trajectory(Precision::Bf16);
    assert_eq!(f32_losses.len(), 24);
    let rels: Vec<f32> = f32_losses
        .iter()
        .zip(&bf16_losses)
        .map(|(&f, &b)| (b - f).abs() / (1.0 + f.abs()))
        .collect();
    let mean_rel = rels.iter().sum::<f32>() / rels.len() as f32;
    let max_rel = rels.iter().copied().fold(0.0f32, f32::max);
    assert!(
        mean_rel < 0.15,
        "bf16 trajectory drifted: mean rel {mean_rel:.4} (per-step {rels:?})"
    );
    assert!(max_rel < 0.5, "bf16 trajectory diverged: max rel {max_rel:.4}");
    let first = bf16_losses[0];
    let last = *bf16_losses.last().unwrap();
    assert!(last.is_finite() && last < 0.9 * first, "bf16 did not train: {first} -> {last}");
    let f_last = *f32_losses.last().unwrap();
    assert!(f_last < 0.9 * f32_losses[0], "f32 baseline did not train");
}

#[test]
fn f16_storage_path_trains_and_stays_finite() {
    let losses = adam_trajectory(Precision::F16);
    assert!(losses.iter().all(|l| l.is_finite()), "f16 produced non-finite loss");
    assert!(
        *losses.last().unwrap() < 0.9 * losses[0],
        "f16 did not train: {} -> {}",
        losses[0],
        losses.last().unwrap()
    );
}

#[test]
fn half_precision_training_is_bitwise_deterministic() {
    // The determinism contract per precision: two identical bf16 runs
    // must produce bitwise-identical losses and parameters.
    let a = adam_trajectory(Precision::Bf16);
    let b = adam_trajectory(Precision::Bf16);
    assert_eq!(a, b, "repeated bf16 training diverged bitwise");
}

#[test]
fn f32_through_precision_path_is_bitwise_the_legacy_path() {
    // with_precision(F32) after with_optim must not change a single bit
    // relative to never touching the precision knob.
    let (tokens, intents, slots) = two_examples();
    let run = |set_precision: bool| {
        let mut t = NativeTrainer::random_init(&tiny_cfg(), 22)
            .unwrap()
            .with_optim(OptimConfig { kind: OptimKind::Adam, ..Default::default() });
        if set_precision {
            t = t.with_precision(Precision::F32);
        }
        for _ in 0..3 {
            t.train_step(&tokens, &intents, &slots, 1e-2).unwrap();
        }
        t.model.to_params()
    };
    assert_eq!(run(false), run(true));
}

#[test]
fn gradients_fd_check_through_the_rounding_round_trip() {
    // Round the layer into bf16/f16-representable storage, run the
    // half-precision forward/backward (rounded caches), and check the
    // analytic gradients against central differences of the f32 loss on
    // the same stored weights.  The residual is the cache-rounding
    // error (~2^-8 relative for bf16), far inside the tolerance.
    for prec in [Precision::Bf16, Precision::F16] {
        let mut rng = SplitMix64::new(31);
        let mut layer = TTLinear::randn(&[3, 2], &[2, 3], 2, 0.5, &mut rng);
        // Round the values but keep f32 storage: the FD loop below
        // perturbs by a non-representable eps, which a packed store
        // would silently re-quantize.
        layer.update_tt(|tt| {
            for core in &mut tt.cores {
                prec.round_slice_in_place(&mut core.data);
            }
        });
        layer.update_bias(|b| prec.round_slice_in_place(b));
        let x = prec.round_tensor(&Tensor::randn(&[4, 6], 1.0, &mut rng));
        let probe = Tensor::randn(&[4, 6], 1.0, &mut rng); // loss = <probe, y>
        let loss = |l: &TTLinear| -> f32 {
            let mut stats = ContractionStats::default();
            let (y, _) = l.forward(&x, &mut stats).unwrap();
            y.data.iter().zip(&probe.data).map(|(a, b)| a * b).sum()
        };
        let mut stats = ContractionStats::default();
        let (_, cache) = layer.forward_prec(&x, prec, &mut stats).unwrap();
        let (_, grads) = layer.backward(&probe, &cache, &mut stats).unwrap();
        let eps = 1e-2f32;
        for k in 0..layer.tt().cores.len() {
            for idx in 0..layer.tt().cores[k].numel() {
                let orig = layer.tt().cores[k].data[idx];
                layer.update_tt(|tt| tt.cores[k].data[idx] = orig + eps);
                let up = loss(&layer);
                layer.update_tt(|tt| tt.cores[k].data[idx] = orig - eps);
                let dn = loss(&layer);
                layer.update_tt(|tt| tt.cores[k].data[idx] = orig);
                let fd = (up - dn) / (2.0 * eps);
                let an = grads.cores[k].data[idx];
                assert!(
                    (fd - an).abs() < 5e-2 * (1.0 + an.abs().max(fd.abs())),
                    "{prec:?} core {k}[{idx}]: fd {fd} vs analytic {an}"
                );
            }
        }
    }
}

#[test]
fn bf16_step_halves_cache_and_state_bytes_end_to_end() {
    // Acceptance: the Eq. 21 cache bytes and the Adam state bytes of a
    // real training step at bf16 are exactly half the f32 figures
    // (element counts are precision-independent).
    let (tokens, intents, slots) = two_examples();
    let run = |prec: Precision| {
        let mut t = NativeTrainer::random_init(&tiny_cfg(), 23)
            .unwrap()
            .with_optim(OptimConfig {
                kind: OptimKind::Adam,
                precision: prec,
                ..Default::default()
            });
        t.train_step(&tokens, &intents, &slots, 1e-2).unwrap();
        let cache_elems = t.last_stats.stored_intermediate_elems;
        (
            cache_elems,
            cache_elems * prec.bytes(),
            t.model.optim.allocated_state_elems(),
            t.model.optim.allocated_state_bytes(),
        )
    };
    let (f_elems, f_bytes, f_state_elems, f_state_bytes) = run(Precision::F32);
    let (b_elems, b_bytes, b_state_elems, b_state_bytes) = run(Precision::Bf16);
    assert_eq!(f_elems, b_elems, "cache element counts must not depend on precision");
    assert_eq!(2 * b_bytes, f_bytes, "bf16 Eq. 21 cache is not half the bytes");
    assert_eq!(f_state_elems, b_state_elems);
    assert_eq!(2 * b_state_bytes, f_state_bytes, "bf16 Adam state is not half the bytes");
    assert!(b_bytes > 0 && b_state_bytes > 0);
}

#[test]
fn eval_stays_consistent_after_half_precision_training() {
    // After bf16 training the exported parameters are all
    // bf16-representable and the model still evaluates finitely through
    // both the training forward and the merged-factor engine.
    let (tokens, intents, slots) = two_examples();
    let mut t = NativeTrainer::random_init(&tiny_cfg(), 24)
        .unwrap()
        .with_optim(OptimConfig {
            kind: OptimKind::Adam,
            precision: Precision::Bf16,
            ..Default::default()
        });
    for _ in 0..4 {
        t.train_step(&tokens, &intents, &slots, 1e-2).unwrap();
    }
    for (name, (_, data)) in t.model.to_params() {
        for v in data {
            assert_eq!(
                Precision::Bf16.round(v).to_bits(),
                v.to_bits(),
                "'{name}' holds a non-bf16-representable value after training"
            );
        }
    }
    let (il, sl) = t.eval(&tokens).unwrap();
    assert!(il.iter().chain(&sl).all(|v| v.is_finite()));
}
