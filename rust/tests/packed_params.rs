//! Packed parameter storage — the tentpole invariants:
//!
//! * **Bitwise parity with rounded-f32 storage.**  A layer whose
//!   parameters live physically `u16`-packed (`set_precision`) computes
//!   the exact same bits — forward, backward, and across a 24-step Adam
//!   trajectory — as a layer holding the identically *rounded* values
//!   in plain f32 storage.  Packing only changes the resting
//!   representation; widen-on-load is exact for both 16-bit formats,
//!   and the PU stage rounds on store, so the packed store is lossless
//!   for everything that ever rests in it.
//! * **Measured byte halving.**  `param_bytes` sums the physical
//!   representation (not an analytic count), so halving the storage
//!   precision halves the at-rest parameter bytes *exactly* — for a
//!   single TT layer, the whole training model, and the merged-factor
//!   inference engine.

use tt_trainer::config::ModelConfig;
use tt_trainer::optim::{ModelOptim, OptimConfig, OptimKind};
use tt_trainer::tensor::{ContractionStats, Precision, Tensor};
use tt_trainer::train::{NativeTrainer, TTLinear};
use tt_trainer::util::rng::SplitMix64;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        n_layers: 1,
        d_hid: 48,
        n_heads: 4,
        seq_len: 8,
        batch: 1,
        vocab: 27,
        n_intents: 5,
        n_slots: 7,
        tt_m: vec![4, 4, 3],
        tt_n: vec![3, 4, 4],
        tt_rank: 3,
        ttm_vocab_modes: vec![3, 3, 3],
        ttm_hid_modes: vec![4, 4, 3],
        ttm_rank: 4,
        pad_id: 0,
        cls_id: 1,
        unk_id: 2,
    }
}

/// A deterministic tiny layer (m = n = 12); two calls with the same
/// seed produce bitwise-identical layers.
fn tiny_layer(seed: u64) -> TTLinear {
    let mut rng = SplitMix64::new(seed);
    TTLinear::randn(&[4, 3], &[3, 4], 3, 0.5, &mut rng)
}

/// Round the layer's values to `prec` while keeping plain f32 storage —
/// the pre-packing representation the packed store must reproduce
/// bitwise.
fn rounded_f32_layer(seed: u64, prec: Precision) -> TTLinear {
    let mut layer = tiny_layer(seed);
    layer.update_tt(|tt| {
        for core in &mut tt.cores {
            prec.round_slice_in_place(&mut core.data);
        }
    });
    layer.update_bias(|b| prec.round_slice_in_place(b));
    layer
}

#[test]
fn packed_forward_is_bitwise_identical_to_rounded_f32_storage() {
    for prec in [Precision::Bf16, Precision::F16] {
        let reference = rounded_f32_layer(91, prec);
        let mut packed = tiny_layer(91);
        packed.set_precision(prec);
        // Same values at rest...
        assert_eq!(packed.tt().cores, reference.tt().cores, "{prec:?}: packing moved bits");
        assert_eq!(&*packed.bias(), &*reference.bias());
        // ...and the same forward bits through the precision-aware path.
        let mut rng = SplitMix64::new(92);
        let x = prec.round_tensor(&Tensor::randn(&[5, 12], 1.0, &mut rng));
        let mut s1 = ContractionStats::default();
        let mut s2 = ContractionStats::default();
        let (y_packed, _) = packed.forward_prec(&x, prec, &mut s1).unwrap();
        let (y_ref, _) = reference.forward_prec(&x, prec, &mut s2).unwrap();
        assert_eq!(y_packed.data, y_ref.data, "{prec:?}: forward diverged");
    }
}

#[test]
fn packed_backward_is_bitwise_identical_to_rounded_f32_storage() {
    for prec in [Precision::Bf16, Precision::F16] {
        let reference = rounded_f32_layer(93, prec);
        let mut packed = tiny_layer(93);
        packed.set_precision(prec);
        let mut rng = SplitMix64::new(94);
        let x = prec.round_tensor(&Tensor::randn(&[5, 12], 1.0, &mut rng));
        let probe = Tensor::randn(&[5, 12], 1.0, &mut rng);
        let run = |l: &TTLinear| {
            let mut s = ContractionStats::default();
            let (_, cache) = l.forward_prec(&x, prec, &mut s).unwrap();
            l.backward(&probe, &cache, &mut s).unwrap()
        };
        let (dx_packed, g_packed) = run(&packed);
        let (dx_ref, g_ref) = run(&reference);
        assert_eq!(dx_packed.data, dx_ref.data, "{prec:?}: dX diverged");
        for (k, (a, b)) in g_packed.cores.iter().zip(&g_ref.cores).enumerate() {
            assert_eq!(a.data, b.data, "{prec:?}: core grad {k} diverged");
        }
        assert_eq!(g_packed.bias, g_ref.bias, "{prec:?}: bias grad diverged");
    }
}

#[test]
fn packed_adam_trajectory_is_bitwise_identical_to_rounded_f32_storage() {
    // 24 Adam steps on the packed layer vs the rounded-f32-stored twin,
    // both driven by the PU stage (which rounds params on store under
    // half precision — exactly what makes the packed store lossless).
    for prec in [Precision::Bf16, Precision::F16] {
        let mut reference = rounded_f32_layer(95, prec);
        let mut packed = tiny_layer(95);
        packed.set_precision(prec);
        let cfg = OptimConfig { kind: OptimKind::Adam, precision: prec, ..Default::default() };
        let mut opt_packed = ModelOptim::new(cfg.clone());
        let mut opt_ref = ModelOptim::new(cfg);
        let hyper = opt_ref.hyper(1e-2);
        let mut rng = SplitMix64::new(96);
        for step in 0..24 {
            let x = prec.round_tensor(&Tensor::randn(&[5, 12], 1.0, &mut rng));
            let probe = Tensor::randn(&[5, 12], 1.0, &mut rng);
            let advance = |l: &mut TTLinear, opt: &mut ModelOptim| {
                let mut s = ContractionStats::default();
                let (_, cache) = l.forward_prec(&x, prec, &mut s).unwrap();
                let (_, grads) = l.backward(&probe, &cache, &mut s).unwrap();
                for (k, g) in grads.cores.iter().enumerate() {
                    l.update_tt(|tt| {
                        opt.step(&format!("core.{k}"), &mut tt.cores[k].data, &g.data, &hyper)
                    });
                }
                l.update_bias(|b| opt.step("bias", b, &grads.bias, &hyper));
            };
            advance(&mut packed, &mut opt_packed);
            advance(&mut reference, &mut opt_ref);
            assert_eq!(
                packed.tt().cores,
                reference.tt().cores,
                "{prec:?}: cores diverged at step {step}"
            );
            assert_eq!(
                &*packed.bias(),
                &*reference.bias(),
                "{prec:?}: bias diverged at step {step}"
            );
        }
    }
}

#[test]
fn halving_the_precision_halves_layer_param_bytes_exactly() {
    let mut layer = tiny_layer(97);
    let f32_bytes = layer.param_bytes();
    assert_eq!(f32_bytes, 4 * layer.param_count() as u64);
    for prec in [Precision::Bf16, Precision::F16] {
        layer.set_precision(prec);
        assert_eq!(2 * layer.param_bytes(), f32_bytes, "{prec:?}: not exactly half");
    }
    // Widening back restores the full f32 footprint.
    layer.set_precision(Precision::F32);
    assert_eq!(layer.param_bytes(), f32_bytes);
}

#[test]
fn halving_the_precision_halves_model_and_engine_param_bytes_exactly() {
    // The whole-model and merged-factor-engine footprints are sums of
    // the physical stores, so the halving is exact end to end — the
    // byte counts depend only on shapes and widths, never on values.
    let cfg = tiny_cfg();
    let at = |prec: Precision| {
        let t = NativeTrainer::random_init(&cfg, 98)
            .unwrap()
            .with_optim(OptimConfig {
                kind: OptimKind::Adam,
                precision: prec,
                ..Default::default()
            });
        let model_bytes = t.model.param_bytes();
        let engine_bytes = t.model.engine().unwrap().param_bytes();
        (model_bytes, engine_bytes)
    };
    let (model_f32, engine_f32) = at(Precision::F32);
    assert!(model_f32 > 0 && engine_f32 > 0);
    for prec in [Precision::Bf16, Precision::F16] {
        let (model_half, engine_half) = at(prec);
        assert_eq!(2 * model_half, model_f32, "{prec:?}: model bytes not exactly half");
        assert_eq!(2 * engine_half, engine_f32, "{prec:?}: engine bytes not exactly half");
    }
}
