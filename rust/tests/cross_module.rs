//! Cross-module property tests: tensor algebra <-> cost model <-> FPGA
//! simulator invariants that span crate boundaries.

use tt_trainer::config::ModelConfig;
use tt_trainer::costmodel::LinearShape;
use tt_trainer::fpga::bram::{self, Strategy};
use tt_trainer::fpga::schedule::CycleModel;
use tt_trainer::tensor::{Tensor, TTMatrix, TTMEmbedding};
use tt_trainer::util::prop;
use tt_trainer::util::rng::SplitMix64;

#[test]
fn tt_svd_of_low_rank_matrix_recovers_rank() {
    // A dense matrix built from a rank-r TT decomposes back at rank r
    // with small error, for random shapes.
    prop::check(61, 10, |rng| {
        let m1 = 2 + rng.below(4) as usize;
        let m2 = 2 + rng.below(4) as usize;
        let n1 = 2 + rng.below(4) as usize;
        let n2 = 2 + rng.below(4) as usize;
        let rank = 1 + rng.below(3) as usize;
        let tt = TTMatrix::randn(&[m1, m2], &[n1, n2], rank, 0.5, rng);
        let w = tt.to_dense().unwrap();
        let tt2 = TTMatrix::from_dense(&w, &[m1, m2], &[n1, n2], 24).unwrap();
        let w2 = tt2.to_dense().unwrap();
        let rel = w2.max_abs_diff(&w) / (1.0 + w.norm());
        assert!(rel < 5e-3, "roundtrip err {rel}");
    });
}

#[test]
fn paper_linear_layer_compresses_120x() {
    // Table II shape: TT params must be ~120x fewer than dense.
    let cfg = ModelConfig::paper(2);
    let dense = cfg.d_hid * cfg.d_hid;
    let tt = cfg.tt_linear_params();
    let ratio = dense as f64 / tt as f64;
    assert!((100.0..140.0).contains(&ratio), "ratio {ratio:.0}");
}

#[test]
fn btt_contraction_agrees_with_dense_at_paper_scale() {
    let mut rng = SplitMix64::new(62);
    let tt = TTMatrix::randn(&[12, 8, 8], &[8, 8, 12], 12, 0.03, &mut rng);
    let x = Tensor::randn(&[768, 32], 1.0, &mut rng);
    let w = tt.to_dense().unwrap();
    let y_dense = w.matmul(&x).unwrap();
    let (y_btt, stats) = tt.matmul_btt(&x).unwrap();
    let scale = y_dense.norm() / (y_dense.numel() as f32).sqrt();
    assert!(y_btt.max_abs_diff(&y_dense) < 1e-3 * (1.0 + scale));
    // The instrumented counts must equal the cost model (Eq. 20/21).
    let shape = LinearShape::uniform(&[12, 8, 8], &[8, 8, 12], 12);
    assert_eq!(stats.muls, shape.btt_muls(32));
    assert_eq!(stats.stored_intermediate_elems, shape.btt_memory(32));
}

#[test]
fn ttm_embedding_rows_bounded() {
    let mut rng = SplitMix64::new(63);
    let e = TTMEmbedding::randn(&[12, 8, 8], &[10, 10, 10], 30, 0.02, &mut rng);
    for t in [0usize, 1, 99, 500, 999] {
        let row = e.lookup(t).unwrap();
        assert_eq!(row.numel(), 768);
        assert!(row.data.iter().all(|x| x.is_finite()));
    }
}

#[test]
fn grouped_bram_fits_all_paper_models() {
    for layers in [2usize, 4, 6] {
        let cores = bram::paper_core_set(layers, 12);
        let k = bram::paper_group_k(3, layers);
        let a = bram::allocate(&cores, Strategy::ReshapeGrouped, k);
        assert!(
            a.total_blocks < tt_trainer::config::U50::BRAM_BLOCKS / 2,
            "L{layers}: {} blocks leaves no room for activations",
            a.total_blocks
        );
    }
}

#[test]
fn latency_scales_linearly_with_depth() {
    // Table V structure: per-epoch latency grows ~linearly in layers.
    let l2 = CycleModel::paper(2).cycles_per_sample() as f64;
    let l4 = CycleModel::paper(4).cycles_per_sample() as f64;
    let l6 = CycleModel::paper(6).cycles_per_sample() as f64;
    let d1 = l4 - l2;
    let d2 = l6 - l4;
    assert!((d1 - d2).abs() / d1 < 0.05, "non-linear depth scaling");
}

#[test]
fn rank_sweep_contraction_engines_stay_consistent() {
    // For every rank in the Fig. 14 sweep, both contraction orders agree
    // with dense and with the analytic model.
    prop::check(64, 8, |rng| {
        let rank = 1 + rng.below(16) as usize;
        let tt = TTMatrix::randn(&[4, 6], &[6, 4], rank, 0.2, rng);
        let x = Tensor::randn(&[24, 8], 1.0, rng);
        let w = tt.to_dense().unwrap();
        let y = w.matmul(&x).unwrap();
        let (y_rl, s_rl) = tt.matmul_right_to_left(&x).unwrap();
        let (y_btt, s_btt) = tt.matmul_btt(&x).unwrap();
        let tol = 1e-4 * (1.0 + y.norm());
        assert!(y_rl.max_abs_diff(&y) < tol);
        assert!(y_btt.max_abs_diff(&y) < tol);
        let shape = LinearShape {
            m_modes: vec![4, 6],
            n_modes: vec![6, 4],
            ranks: tt.ranks.clone(),
        };
        assert_eq!(s_rl.muls, shape.tt_rl_muls(8));
        assert_eq!(s_btt.muls, shape.btt_muls(8));
    });
}
