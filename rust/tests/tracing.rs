//! Tracing + metrics integration suite — the observability contracts:
//!
//! * **Deterministic span trees**: two identical train steps produce
//!   the identical `(name, cat, depth)` sequence on the training
//!   thread, with the paper's FP/BP/PU stage spans present and the BTT
//!   contraction spans nested inside them.
//! * **Near-zero disabled cost**: an instrumented site with tracing
//!   off is one relaxed atomic load; the measured per-call overhead
//!   stays under a conservative bound, and enabling tracing does not
//!   perturb training (bitwise-identical parameters).
//! * **Chrome-JSON export**: escaping round-trips through the in-repo
//!   JSON parser and the document carries per-thread lanes.
//! * **Gauge consistency**: the live byte gauges sampled inside
//!   `train_step` agree with `measure_eq21_cache_bytes`, the analytic
//!   `ResourceReport` and the optimizer's own accounting across
//!   {f32, bf16} x {cache-all, recompute}.
//!
//! Every test takes `trace::TestSession` — the tracer, registry and
//! enabled flag are process-global, and `cargo test` runs threads in
//! parallel.

use std::sync::Arc;
use tt_trainer::config::ModelConfig;
use tt_trainer::engine::NativeEngine;
use tt_trainer::fpga::resources;
use tt_trainer::optim::{OptimConfig, OptimKind};
use tt_trainer::serve::{ServeConfig, Server};
use tt_trainer::tensor::{Precision, Tensor};
use tt_trainer::trace;
use tt_trainer::trace::SpanEvent;
use tt_trainer::train::{CheckpointPolicy, NativeTrainModel, NativeTrainer};
use tt_trainer::util::json::Value;
use tt_trainer::util::rng::SplitMix64;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        n_layers: 1,
        d_hid: 48,
        n_heads: 4,
        seq_len: 8,
        batch: 1,
        vocab: 27,
        n_intents: 5,
        n_slots: 7,
        tt_m: vec![4, 4, 3],
        tt_n: vec![3, 4, 4],
        tt_rank: 3,
        ttm_vocab_modes: vec![3, 3, 3],
        ttm_hid_modes: vec![4, 4, 3],
        ttm_rank: 4,
        pad_id: 0,
        cls_id: 1,
        unk_id: 2,
    }
}

fn example() -> (Vec<i32>, Vec<i32>, Vec<i32>) {
    (vec![1, 5, 9, 13, 4, 0, 0, 0], vec![2], vec![0, 1, 2, 3, 1, 0, 0, 0])
}

/// One traced train step on a fresh model; returns the drained events.
fn traced_step(seed: u64) -> Vec<SpanEvent> {
    let (tokens, intent, slots) = example();
    let mut model = NativeTrainModel::random_init(&tiny_cfg(), seed).unwrap();
    trace::set_enabled(true);
    model.train_step(&tokens, &intent, &slots, 1e-2).unwrap();
    trace::set_enabled(false);
    trace::drain()
}

#[test]
fn span_trees_are_deterministic_and_stage_structured() {
    let _s = trace::TestSession::begin();
    let run_a = traced_step(91);
    let run_b = traced_step(91);
    // The training thread is the one carrying the `train`-cat spans
    // (pool jobs, if any, land on the tt-matmul lanes).
    let train_tid = |ev: &[SpanEvent]| {
        ev.iter().find(|e| e.cat == "train").expect("no train spans").tid
    };
    let on_thread = |ev: &[SpanEvent], tid: u64| -> Vec<(String, &'static str, u32)> {
        ev.iter()
            .filter(|e| e.tid == tid)
            .map(|e| (e.name.clone(), e.cat, e.depth))
            .collect()
    };
    let a = on_thread(&run_a, train_tid(&run_a));
    let b = on_thread(&run_b, train_tid(&run_b));
    assert_eq!(a, b, "span tree differs between identical runs");

    // Every stage of the paper's loop shows up, in FP -> BP/PU order.
    let names: Vec<&str> = a.iter().map(|(n, _, _)| n.as_str()).collect();
    let first = |want: &str| {
        names.iter().position(|n| *n == want).unwrap_or_else(|| panic!("missing span {want}"))
    };
    assert!(first("fp.embed") < first("fp.layer0"));
    assert!(first("fp.layer0") < first("fp.heads"));
    assert!(first("fp.heads") < first("bp.heads"));
    assert!(first("bp.heads") < first("pu.heads"));
    for want in ["bp.pool", "pu.pool", "bp.layer0", "pu.layer0", "bp.embed", "pu.embed"] {
        first(want);
    }
    // BTT contraction spans exist and nest inside a stage span.
    let tt: Vec<_> = a.iter().filter(|(_, cat, _)| *cat == "ttlinear").collect();
    assert!(!tt.is_empty(), "no ttlinear contraction spans");
    for (name, _, depth) in &tt {
        assert!(
            matches!(name.as_str(), "merge_left" | "merge_right" | "apply"),
            "unexpected ttlinear span {name}"
        );
        assert!(*depth >= 1, "ttlinear span {name} not nested in a stage span");
    }

    // The FP/BP/PU aggregation covers exactly the three stages and its
    // shares form a partition.
    let rows = trace::stage_breakdown(&run_a);
    let stages: Vec<&str> = rows.iter().map(|r| r.stage.as_str()).collect();
    assert_eq!(&stages[..3], &["fp", "bp", "pu"]);
    let share_sum: f64 = rows.iter().take(3).map(|r| r.share).sum();
    assert!((share_sum - 1.0).abs() < 1e-9, "stage shares sum to {share_sum}");
    assert!(rows.iter().take(3).all(|r| r.total_us > 0.0 && r.spans > 0));
}

#[test]
fn disabled_overhead_is_bounded_and_training_unperturbed() {
    let _s = trace::TestSession::begin();
    // Warm the thread-local + branch predictor, then measure.
    trace::disabled_overhead_ns(10_000);
    let ns = trace::disabled_overhead_ns(1_000_000);
    assert!(
        ns < 1_000.0,
        "disabled instrumentation costs {ns:.1} ns/call — contract is one relaxed atomic load"
    );

    // Observation-only: a traced step leaves bitwise the parameters of
    // an untraced one (spans/gauges never feed back into compute).
    let (tokens, intent, slots) = example();
    let run = |on: bool| {
        let mut model = NativeTrainModel::random_init(&tiny_cfg(), 92).unwrap();
        trace::set_enabled(on);
        let (loss, _) = model.train_step(&tokens, &intent, &slots, 1e-2).unwrap();
        trace::set_enabled(false);
        trace::reset();
        (loss, model.to_params())
    };
    let (loss_off, params_off) = run(false);
    let (loss_on, params_on) = run(true);
    assert_eq!(loss_off, loss_on, "tracing changed the loss");
    assert_eq!(params_off, params_on, "tracing changed the parameters");
}

#[test]
fn chrome_json_escapes_and_round_trips_through_the_parser() {
    let _s = trace::TestSession::begin();
    let nasty = "fp.\"layer\\0\"\n\ttab\u{1}end";
    let events = vec![
        SpanEvent {
            name: nasty.to_string(),
            cat: "train",
            thread: "main \"lane\"".to_string(),
            tid: 1,
            depth: 0,
            seq: 0,
            start_us: 10.0,
            dur_us: 2.5,
        },
        SpanEvent {
            name: "job".to_string(),
            cat: "pool",
            thread: "tt-matmul-0".to_string(),
            tid: 2,
            depth: 0,
            seq: 0,
            start_us: 11.0,
            dur_us: 1.0,
        },
    ];
    let json = trace::to_chrome_json(&events);
    let doc = Value::parse(&json).expect("exported trace is not valid JSON");
    let items = doc.get("traceEvents").and_then(Value::as_arr).expect("no traceEvents array");
    // 2 lanes -> 2 metadata events, then the 2 complete events.
    assert_eq!(items.len(), 4);
    let metas: Vec<_> =
        items.iter().filter(|e| e.get("ph").and_then(Value::as_str) == Some("M")).collect();
    assert_eq!(metas.len(), 2);
    assert_eq!(
        metas[0].get("args").unwrap().get("name").and_then(Value::as_str),
        Some("main \"lane\"")
    );
    let xs: Vec<_> =
        items.iter().filter(|e| e.get("ph").and_then(Value::as_str) == Some("X")).collect();
    assert_eq!(xs.len(), 2);
    // The escaping round-trips: the parsed name is the original string.
    assert_eq!(xs[0].get("name").and_then(Value::as_str), Some(nasty));
    assert_eq!(xs[0].get("cat").and_then(Value::as_str), Some("train"));
    assert_eq!(xs[0].get("ts").and_then(Value::as_f64), Some(10.0));
    assert_eq!(xs[0].get("dur").and_then(Value::as_f64), Some(2.5));
    assert_eq!(xs[0].get("args").unwrap().get("depth").and_then(Value::as_f64), Some(0.0));
    assert_eq!(xs[1].get("tid").and_then(Value::as_f64), Some(2.0));
}

#[test]
fn byte_gauges_agree_with_resource_report_across_grid() {
    // The live gauges sampled at the stage boundary inside `train_step`
    // must agree with (1) the executed cache measurement, (2) the
    // analytic ResourceReport, (3) the optimizer's own allocation
    // accounting and (4) an independent parameter-byte sum — across
    // precision x checkpoint policy.
    let _s = trace::TestSession::begin();
    let (tokens, intent, slots) = example();
    let cfg = tiny_cfg();
    for prec in [Precision::F32, Precision::Bf16] {
        for policy in [CheckpointPolicy::CacheAll, CheckpointPolicy::Recompute] {
            let mut model = NativeTrainModel::random_init(&cfg, 93).unwrap();
            model.set_optim(OptimConfig { kind: OptimKind::Adam, ..Default::default() });
            model.set_precision(prec);
            model.checkpoint = policy.clone();
            trace::set_enabled(true);
            model.train_step(&tokens, &intent, &slots, 1e-2).unwrap();
            trace::set_enabled(false);
            let ctx = format!("{prec:?}/{}", policy.name());

            let eq21 = trace::gauge("eq21_cache_bytes").expect("eq21 gauge not set");
            let measured = model.measure_eq21_cache_bytes(&tokens).unwrap();
            let report = resources::report_for_policy(&cfg, OptimKind::Adam, prec, &policy);
            assert_eq!(eq21, measured, "[{ctx}] gauge vs executed caches");
            assert_eq!(eq21, report.eq21_cache_bytes, "[{ctx}] gauge vs ResourceReport");

            let opt = trace::gauge("optim_state_bytes").expect("optimizer gauge not set");
            assert_eq!(opt, model.optim.allocated_state_bytes(), "[{ctx}] optimizer bytes");
            assert!(opt > 0, "[{ctx}] Adam allocated no moments");

            let pb = trace::gauge("param_bytes").expect("param gauge not set");
            let elems: u64 =
                model.to_params().values().map(|(_, v)| v.len() as u64).sum();
            assert_eq!(pb, elems * prec.bytes(), "[{ctx}] packed param bytes");

            assert_eq!(trace::counter("train_steps_total"), 1, "[{ctx}]");
            trace::reset();
            trace::metrics::reset();
        }
    }
}

#[test]
fn pool_jobs_span_on_worker_lanes() {
    // The worker-pool path only engages above the parallel threshold
    // and when the host has >= 2 cores; skip (trivially pass) on
    // single-core runners where the pool has no workers.
    let cores = std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1);
    if cores < 2 {
        return;
    }
    let _s = trace::TestSession::begin();
    let mut rng = SplitMix64::new(94);
    // 256^3 multiply-accumulates: above the pool dispatch threshold.
    let a = Tensor::randn(&[256, 256], 1.0, &mut rng);
    let b = Tensor::randn(&[256, 256], 1.0, &mut rng);
    trace::set_enabled(true);
    a.matmul(&b).unwrap();
    trace::set_enabled(false);
    let ev = trace::drain();
    let jobs: Vec<_> =
        ev.iter().filter(|e| e.cat == "pool" && e.name == "job").collect();
    assert!(!jobs.is_empty(), "no pool job spans from a parallel matmul");
    assert!(
        jobs.iter().all(|e| e.thread.starts_with("tt-matmul-")),
        "pool spans not on worker lanes: {:?}",
        jobs.iter().map(|e| &e.thread).collect::<Vec<_>>()
    );
}

#[test]
fn serve_spans_cover_the_request_lifecycle() {
    let _s = trace::TestSession::begin();
    let cfg = tiny_cfg();
    let params = NativeTrainer::random_init(&cfg, 95).unwrap().model.to_params();
    let engine = Arc::new(NativeEngine::from_params(&cfg, &params).unwrap());
    trace::set_enabled(true);
    let server = Server::start(engine, ServeConfig::no_batching()).unwrap();
    server.handle().submit(&[1, 5, 9, 13]).unwrap().wait().unwrap();
    server.shutdown();
    trace::set_enabled(false);
    let ev = trace::drain();
    for want in ["admit", "queue", "batch_execute", "respond"] {
        assert!(
            ev.iter().any(|e| e.cat == "serve" && e.name == want),
            "missing serve span {want}"
        );
    }
    // The executor's engine call shows up on the serve-executor lane.
    let exec = ev
        .iter()
        .find(|e| e.cat == "serve" && e.name == "batch_execute")
        .unwrap();
    assert_eq!(exec.thread, "serve-executor");
    assert!(
        ev.iter().any(|e| e.cat == "engine" && e.name == "forward" && e.tid == exec.tid),
        "engine forward span missing from the executor lane"
    );
}
