//! Native-training integration tests — all runnable with **no HLO
//! artifacts and no XLA**: a full forward + backward + SGD step through
//! the rust-native backend, finite-difference gradient parity, cost
//! model validation of the BP stage, and (artifact-gated, `pjrt`
//! feature) a native-vs-PJRT loss-trajectory parity run.

use tt_trainer::config::ModelConfig;
use tt_trainer::coordinator::{TrainBackend, Trainer};
use tt_trainer::costmodel::LinearShape;
use tt_trainer::data::Dataset;
use tt_trainer::tensor::{ContractionStats, Tensor};
use tt_trainer::train::{NativeTrainer, TTLinear};
use tt_trainer::util::rng::SplitMix64;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        n_layers: 1,
        d_hid: 48,
        n_heads: 4,
        seq_len: 8,
        batch: 1,
        vocab: 27,
        n_intents: 5,
        n_slots: 7,
        tt_m: vec![4, 4, 3],
        tt_n: vec![3, 4, 4],
        tt_rank: 3,
        ttm_vocab_modes: vec![3, 3, 3],
        ttm_hid_modes: vec![4, 4, 3],
        ttm_rank: 4,
        pad_id: 0,
        cls_id: 1,
        unk_id: 2,
    }
}

/// Deterministic batch-1 examples at the tiny config (the grammar
/// generator targets the paper's 26-intent label space, so tiny-config
/// tests roll their own labels).
fn tiny_examples(cfg: &ModelConfig, seed: u64, n: usize) -> Vec<(Vec<i32>, i32, Vec<i32>)> {
    let mut rng = SplitMix64::new(seed);
    (0..n)
        .map(|_| {
            let len = 3 + rng.below(cfg.seq_len as u64 - 3) as usize;
            let mut tokens = vec![cfg.pad_id; cfg.seq_len];
            let mut slots = vec![0i32; cfg.seq_len];
            tokens[0] = cfg.cls_id;
            for p in 1..len {
                tokens[p] = 3 + rng.below(cfg.vocab as u64 - 3) as i32;
                slots[p] = rng.below(cfg.n_slots as u64) as i32;
            }
            let intent = rng.below(cfg.n_intents as u64) as i32;
            (tokens, intent, slots)
        })
        .collect()
}

#[test]
fn full_native_train_step_without_artifacts() {
    // Acceptance: a complete FP -> BP -> PU step runs with nothing but
    // the crate itself.
    let cfg = tiny_cfg();
    let mut backend = NativeTrainer::random_init(&cfg, 1).unwrap();
    let (tokens, intent, slots) = tiny_examples(&cfg, 2, 1).remove(0);
    let out = backend.train_step(&tokens, &[intent], &slots, 0.01).unwrap();
    assert!(out.loss.is_finite() && out.loss > 0.0);
    assert!(backend.last_stats.muls > 0, "step not instrumented");
    // Eval contract matches the engine's.
    let (il, sl) = backend.eval(&tokens).unwrap();
    assert_eq!(il.len(), cfg.n_intents);
    assert_eq!(sl.len(), cfg.seq_len * cfg.n_slots);
}

#[test]
fn native_training_reduces_loss() {
    let cfg = tiny_cfg();
    let backend = NativeTrainer::random_init(&cfg, 3).unwrap();
    let mut trainer = Trainer::new(backend, 0.05);
    let examples = tiny_examples(&cfg, 4, 4);
    let mut mean_first = 0.0;
    let mut mean_last = 0.0;
    for round in 0..20 {
        let mut total = 0.0;
        for (tokens, intent, slots) in &examples {
            let out = trainer
                .backend
                .train_step(tokens, &[*intent], slots, trainer.lr)
                .unwrap();
            total += out.loss;
        }
        let mean = total / examples.len() as f32;
        if round == 0 {
            mean_first = mean;
        }
        mean_last = mean;
    }
    assert!(
        mean_last < 0.7 * mean_first,
        "loss did not decrease: first {mean_first:.4} last {mean_last:.4}"
    );
}

#[test]
fn trainer_loop_drives_native_backend() {
    // The generic coordinator (epochs, metrics, mean-loss contract)
    // over the native backend, on real grammar data at the paper config
    // scale-down: use the paper config's label spaces with 1 layer to
    // keep runtime small.
    let mut cfg = ModelConfig::paper(1);
    cfg.seq_len = 16; // shorter sequences: faster test, same paths
    let backend = NativeTrainer::random_init(&cfg, 5).unwrap();
    let mut trainer = Trainer::new(backend, 4e-3);
    let data = Dataset::synth(&cfg, 42, 6);
    let mean = trainer.train_steps(&data, 6).unwrap();
    assert!(mean.is_finite() && mean > 0.0);
    assert_eq!(trainer.metrics.steps, 6);
    // train_steps returns the running mean, not the last loss.
    let by_hand: f32 =
        trainer.metrics.losses.iter().map(|&(_, l)| l).sum::<f32>() / 6.0;
    assert!((mean - by_hand).abs() < 1e-6);
    // Zero steps: defined result, no NaN.
    assert_eq!(trainer.train_steps(&data, 0).unwrap(), 0.0);
    // Evaluation runs through the same backend.
    let ev = trainer.evaluate(&data, Some(4)).unwrap();
    assert!(ev.intent_acc >= 0.0 && ev.slot_acc >= 0.0);
}

#[test]
fn batched_trainer_runs_every_optimizer_end_to_end() {
    // The coordinator's batch iterator over the grammar dataset through
    // each PU-stage rule: `--optimizer X --batch 4` end to end.
    use tt_trainer::optim::{OptimConfig, OptimKind};
    let mut cfg = ModelConfig::paper(1);
    cfg.seq_len = 16;
    let data = Dataset::synth(&cfg, 42, 10);
    for kind in OptimKind::all() {
        let optim = OptimConfig { kind, batch_size: 4, ..Default::default() };
        let backend = NativeTrainer::random_init(&cfg, 5)
            .unwrap()
            .with_optim(optim);
        let mut trainer = Trainer::with_batch(backend, kind.default_lr(), 4);
        // One epoch over 10 examples = 3 optimizer steps (4 + 4 + 2).
        let mean = trainer.train_epoch(&data, None).unwrap();
        assert!(mean.is_finite() && mean > 0.0, "{kind:?}: bad epoch loss {mean}");
        assert_eq!(trainer.metrics.steps, 3, "{kind:?}: batch iterator step count");
        assert_eq!(trainer.metrics.tokens, 10 * cfg.seq_len, "{kind:?}: token accounting");
        assert_eq!(trainer.metrics.epoch_secs.len(), 1, "{kind:?}: epoch wall-clock");
        // Step-driven training continues through the split in batches.
        trainer.train_steps(&data, 2).unwrap();
        assert_eq!(trainer.metrics.steps, 5);
        // Evaluation still runs per example.
        let ev = trainer.evaluate(&data, Some(4)).unwrap();
        assert!(ev.intent_acc >= 0.0 && ev.slot_acc >= 0.0);
    }
}

#[test]
fn checkpoint_roundtrip_survives_adam_batch_training() {
    // Parameters (not optimizer state) checkpoint and restore bitwise
    // after batched Adam training — the PJRT-interchange contract.
    use tt_trainer::optim::{OptimConfig, OptimKind};
    let cfg = tiny_cfg();
    let examples = tiny_examples(&cfg, 9, 4);
    let mut batch_tokens = Vec::new();
    let mut batch_intents = Vec::new();
    let mut batch_slots = Vec::new();
    for (tokens, intent, slots) in &examples {
        batch_tokens.extend_from_slice(tokens);
        batch_intents.push(*intent);
        batch_slots.extend_from_slice(slots);
    }
    let mut t = NativeTrainer::random_init(&cfg, 31)
        .unwrap()
        .with_optim(OptimConfig { kind: OptimKind::Adam, ..Default::default() });
    t.train_step(&batch_tokens, &batch_intents, &batch_slots, 1e-3)
        .unwrap();
    let before = t.eval(&batch_tokens).unwrap();
    let dir = std::env::temp_dir().join(format!("native_ckpt_adam_{}", std::process::id()));
    t.save_checkpoint(&dir).unwrap();
    t.train_step(&batch_tokens, &batch_intents, &batch_slots, 0.5)
        .unwrap();
    assert_ne!(t.eval(&batch_tokens).unwrap(), before);
    t.load_checkpoint(&dir).unwrap();
    assert_eq!(t.eval(&batch_tokens).unwrap(), before);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn pjrt_style_backend_rejects_oversized_batches() {
    // The native backend takes any B; a backend compiled for batch 1
    // (`supports_batch` default) must be refused by the coordinator
    // instead of silently mis-shaping the literals.
    struct FixedBatch(NativeTrainer);
    impl tt_trainer::coordinator::TrainBackend for FixedBatch {
        fn backend_name(&self) -> &'static str {
            "fixed"
        }
        fn config(&self) -> &ModelConfig {
            self.0.config()
        }
        fn train_step(
            &mut self,
            tokens: &[i32],
            intent: &[i32],
            slots: &[i32],
            lr: f32,
        ) -> anyhow::Result<tt_trainer::coordinator::StepOutput> {
            self.0.train_step(tokens, intent, slots, lr)
        }
        fn eval(&self, tokens: &[i32]) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
            self.0.eval(tokens)
        }
        fn save_checkpoint(&self, dir: &std::path::Path) -> anyhow::Result<()> {
            self.0.save_checkpoint(dir)
        }
        fn load_checkpoint(&mut self, dir: &std::path::Path) -> anyhow::Result<()> {
            self.0.load_checkpoint(dir)
        }
    }
    // Grammar data needs the paper label spaces (tiny_cfg's 5-intent
    // head would reject the generator's 26 intents).
    let mut cfg = ModelConfig::paper(1);
    cfg.seq_len = 16;
    let backend = FixedBatch(NativeTrainer::random_init(&cfg, 7).unwrap());
    let mut trainer = Trainer::with_batch(backend, 0.01, 2);
    let data = Dataset::synth(&cfg, 42, 4);
    let err = trainer.train_steps(&data, 1);
    assert!(err.is_err(), "batch-2 step on a batch-1 backend must fail");
    // Batch 1 still works through the same wrapper.
    let mut trainer = Trainer::new(trainer.backend, 0.01);
    trainer.train_steps(&data, 1).unwrap();
}

#[test]
fn tt_layer_gradients_match_finite_differences() {
    // Acceptance: relative error < 1e-3 on a tiny TT layer.
    let mut rng = SplitMix64::new(6);
    let mut layer = TTLinear::randn(&[3, 2], &[2, 3], 2, 0.5, &mut rng);
    let x = Tensor::randn(&[4, 6], 1.0, &mut rng);
    let probe = Tensor::randn(&[4, 6], 1.0, &mut rng); // loss = <probe, y>
    let loss = |l: &TTLinear| -> f32 {
        let mut stats = ContractionStats::default();
        let (y, _) = l.forward(&x, &mut stats).unwrap();
        y.data.iter().zip(&probe.data).map(|(a, b)| a * b).sum()
    };
    let mut stats = ContractionStats::default();
    let (y, cache) = layer.forward(&x, &mut stats).unwrap();
    assert_eq!(y.shape, vec![4, 6]);
    let (_, grads) = layer.backward(&probe, &cache, &mut stats).unwrap();
    let eps = 1e-2f32;
    for k in 0..layer.tt().cores.len() {
        for idx in 0..layer.tt().cores[k].numel() {
            let orig = layer.tt().cores[k].data[idx];
            layer.update_tt(|tt| tt.cores[k].data[idx] = orig + eps);
            let up = loss(&layer);
            layer.update_tt(|tt| tt.cores[k].data[idx] = orig - eps);
            let dn = loss(&layer);
            layer.update_tt(|tt| tt.cores[k].data[idx] = orig);
            let fd = (up - dn) / (2.0 * eps);
            let an = grads.cores[k].data[idx];
            let rel = (fd - an).abs() / (1.0 + an.abs());
            assert!(rel < 1e-3, "core {k}[{idx}]: fd {fd} vs analytic {an} (rel {rel})");
        }
    }
    for idx in 0..layer.bias().len() {
        let orig = layer.bias()[idx];
        layer.update_bias(|b| b[idx] = orig + eps);
        let up = loss(&layer);
        layer.update_bias(|b| b[idx] = orig - eps);
        let dn = loss(&layer);
        layer.update_bias(|b| b[idx] = orig);
        let fd = (up - dn) / (2.0 * eps);
        let an = grads.bias[idx];
        assert!((fd - an).abs() / (1.0 + an.abs()) < 1e-3, "bias[{idx}]: {fd} vs {an}");
    }
}

#[test]
fn whole_model_gradients_match_finite_differences() {
    // Spot-check the end-to-end chain rule (embedding -> attention ->
    // FFN -> heads -> joint CE loss) against central differences on the
    // intent head, the positional table and an embedding core.
    let cfg = tiny_cfg();
    let (tokens, intent, slots) = tiny_examples(&cfg, 7, 1).remove(0);
    // Evaluate the loss at a parameter map via a zero-lr step (lr = 0
    // makes the fused update a no-op).
    let loss_of = |params: &tt_trainer::inference::ParamMap| -> f32 {
        let mut probe = NativeTrainer::from_params(&cfg, params).unwrap();
        probe
            .train_step(&tokens, &[intent], &slots, 0.0)
            .unwrap()
            .loss
    };
    let base = NativeTrainer::random_init(&cfg, 8).unwrap();
    // Analytic gradients via one lr=1 step: every gradient is computed
    // against the pre-step parameters, so p' = p - g, i.e. g = p - p'.
    let before = base.model.to_params();
    let mut stepped = NativeTrainer::from_params(&cfg, &before).unwrap();
    stepped.train_step(&tokens, &[intent], &slots, 1.0).unwrap();
    let after = stepped.model.to_params();

    let eps = 2e-2f32;
    for (name, picks) in [
        ("cls.intent_w", vec![0usize, 17, 91]),
        ("embed.pos", vec![3usize, 50, 200]),
        ("embed.ttm.1", vec![1usize, 40, 100]),
        ("layers.0.wq.cores.2", vec![0usize, 10, 26]),
        ("layers.0.ln1.g", vec![0usize, 20]),
    ] {
        let (_, before_data) = &before[name];
        let (_, after_data) = &after[name];
        for idx in picks {
            let analytic = before_data[idx] - after_data[idx]; // g = p - p'
            let mut probe_map = before.clone();
            probe_map.get_mut(name).unwrap().1[idx] = before_data[idx] + eps;
            let up = loss_of(&probe_map);
            probe_map.get_mut(name).unwrap().1[idx] = before_data[idx] - eps;
            let dn = loss_of(&probe_map);
            let fd = (up - dn) / (2.0 * eps);
            assert!(
                (fd - analytic).abs() < 5e-3 * (1.0 + analytic.abs()),
                "{name}[{idx}]: fd {fd} vs analytic {analytic}"
            );
        }
    }
}

#[test]
fn native_backward_validates_cost_model() {
    // The BP stage's executed multiplies equal the analytic 2x Eq. 20
    // at the paper's layer shape, and the training cache equals Eq. 21.
    let mut rng = SplitMix64::new(9);
    let layer = TTLinear::randn(&[12, 8, 8], &[8, 8, 12], 12, 0.03, &mut rng);
    let k_dim = 32usize;
    let x = Tensor::randn(&[k_dim, 768], 1.0, &mut rng);
    let shape = LinearShape::paper();
    let mut fwd = ContractionStats::default();
    let (y, cache) = layer.forward(&x, &mut fwd).unwrap();
    assert_eq!(fwd.muls, shape.btt_muls(k_dim as u64));
    assert_eq!(fwd.stored_intermediate_elems, shape.btt_memory(k_dim as u64));
    let dy = Tensor::randn(&[k_dim, y.shape[1]], 1.0, &mut rng);
    let mut bwd = ContractionStats::default();
    layer.backward(&dy, &cache, &mut bwd).unwrap();
    assert_eq!(bwd.muls, shape.btt_bwd_muls(k_dim as u64));
}

/// Artifact-gated cross-backend parity: the native BP must track the
/// JAX-autodiff PJRT path's loss trajectory from identical parameters.
#[cfg(feature = "pjrt")]
mod pjrt_parity {
    use super::*;
    use tt_trainer::inference::params_from_engine;
    use tt_trainer::runtime::{Engine, Manifest};

    #[test]
    fn loss_trajectory_matches_pjrt_over_ten_steps() {
        let Ok(m) = Manifest::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")) else {
            eprintln!("skipping: artifacts/ not present (run `make artifacts`)");
            return;
        };
        let spec = m.variant("tt_L2").unwrap();
        let mut engine = match Engine::load(spec) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("skipping: PJRT unavailable ({e})");
                return;
            }
        };
        let cfg = spec.config.clone();
        let mut native =
            NativeTrainer::from_params(&cfg, &params_from_engine(&engine).unwrap()).unwrap();
        let data = Dataset::synth(&cfg, 42, 10);
        let lr = 4e-3f32;
        for (i, ex) in data.examples.iter().enumerate() {
            let lp = engine
                .train_step(&ex.tokens, &[ex.intent], &ex.slots, lr)
                .unwrap()
                .loss;
            let ln = native
                .train_step(&ex.tokens, &[ex.intent], &ex.slots, lr)
                .unwrap()
                .loss;
            let rel = (lp - ln).abs() / (1.0 + lp.abs());
            assert!(rel < 5e-2, "step {i}: pjrt loss {lp} vs native {ln} (rel {rel})");
        }
    }
}
