//! Block-scaled int8 storage suite — all runnable with no artifacts:
//!
//! * quantize/dequantize error bounds, the exact fixed-point property
//!   for every int8 code, and the amax = 0 / subnormal-block edge
//!   cases, through the public `tensor::precision` surface,
//! * int8 training is bitwise deterministic across reruns,
//! * the 24-step int8 Adam loss trajectory stays within (generous)
//!   tolerance of bf16 and actually trains,
//! * at-rest `param_bytes` / Adam state bytes land in the
//!   quarter-of-f32 class with the per-block scale sidecar charged
//!   exactly (1 byte per element + 4 bytes per 64-element block),
//! * the dynamic loss scaler backs off on a non-finite step, skips the
//!   update entirely, checkpoints with the optimizer state, and the
//!   restored run resumes bitwise — at int8 and at f16 (the
//!   spiked-batch overflow regression).

use tt_trainer::config::ModelConfig;
use tt_trainer::coordinator::TrainBackend;
use tt_trainer::engine::ParamMap;
use tt_trainer::optim::{OptimConfig, OptimKind, LOSS_SCALE_INIT};
use tt_trainer::tensor::precision::{int8_block_scale, int8_dequantize, int8_quantize};
use tt_trainer::tensor::{PackedVec, Precision, ScaledBlockVec, INT8_BLOCK};
use tt_trainer::train::NativeTrainer;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        n_layers: 1,
        d_hid: 48,
        n_heads: 4,
        seq_len: 8,
        batch: 1,
        vocab: 27,
        n_intents: 5,
        n_slots: 7,
        tt_m: vec![4, 4, 3],
        tt_n: vec![3, 4, 4],
        tt_rank: 3,
        ttm_vocab_modes: vec![3, 3, 3],
        ttm_hid_modes: vec![4, 4, 3],
        ttm_rank: 4,
        pad_id: 0,
        cls_id: 1,
        unk_id: 2,
    }
}

/// Two fixed examples at the tiny config (tokens, intents, slots).
fn two_examples() -> (Vec<i32>, Vec<i32>, Vec<i32>) {
    let tokens = vec![
        1, 5, 9, 13, 4, 0, 0, 0, // example 0
        1, 3, 2, 7, 11, 26, 6, 0, // example 1
    ];
    let intents = vec![2, 4];
    let slots = vec![
        0, 1, 2, 3, 1, 0, 0, 0, //
        0, 2, 2, 4, 5, 6, 1, 0, //
    ];
    (tokens, intents, slots)
}

fn int8_trainer(seed: u64) -> NativeTrainer {
    NativeTrainer::random_init(&tiny_cfg(), seed).unwrap().with_optim(OptimConfig {
        kind: OptimKind::Adam,
        precision: Precision::Int8,
        ..Default::default()
    })
}

/// Run 24 batched Adam steps at the given storage precision and return
/// the per-step losses plus the final exported parameters.
fn adam_trajectory(prec: Precision) -> (Vec<f32>, ParamMap) {
    let (tokens, intents, slots) = two_examples();
    let mut t = NativeTrainer::random_init(&tiny_cfg(), 21)
        .unwrap()
        .with_optim(OptimConfig { kind: OptimKind::Adam, precision: prec, ..Default::default() });
    let losses = (0..24)
        .map(|_| t.train_step(&tokens, &intents, &slots, 1e-2).unwrap().loss)
        .collect();
    (losses, t.model.to_params())
}

#[test]
fn quantize_dequantize_error_is_within_half_a_step() {
    // |x - dequant(quant(x))| <= scale/2 + snap slop for every in-range
    // value: RNE to the nearest code, with the bf16-snapped scale
    // widening the step by at most 2^-8 relative.
    let vals: Vec<f32> = (0..256).map(|i| ((i * 37 + 11) % 509) as f32 * 0.013 - 3.2).collect();
    let v = ScaledBlockVec::from_f32(&vals);
    assert_eq!(v.len(), vals.len());
    for (blk, chunk) in vals.chunks(INT8_BLOCK).enumerate() {
        let amax = chunk.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        let scale = v.scales()[blk];
        // The stored scale is the bf16-snapped amax/127 (snap is RNE,
        // so within 2^-8 relative of the exact quotient).
        assert_eq!(scale, int8_block_scale(amax), "block {blk} scale");
        assert!((scale - amax / 127.0).abs() <= amax / 127.0 * (1.0 / 256.0) + f32::MIN_POSITIVE);
        for (i, &x) in chunk.iter().enumerate() {
            let got = v.get(blk * INT8_BLOCK + i);
            // Half a quantization step, plus the clamp slack when the
            // snapped scale landed just below amax/127.
            let bound = scale * 0.51 + 1e-30;
            assert!(
                (x - got).abs() <= bound,
                "block {blk} elem {i}: {x} -> {got} (scale {scale})"
            );
        }
    }
    // Round-on-store fixed point: re-quantizing the dequantized values
    // reproduces the identical codes and scales, bitwise.
    let again = ScaledBlockVec::from_f32(&v.to_f32());
    assert_eq!(v.codes(), again.codes());
    assert_eq!(
        v.scales().iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
        again.scales().iter().map(|s| s.to_bits()).collect::<Vec<_>>()
    );
}

#[test]
fn every_int8_code_survives_quantize_dequantize() {
    // quantize(dequantize(q)) == q for every representable code, at a
    // spread of scales: the stored representation is a fixed point.
    for &amax in &[1.0f32, 0.37, 1024.0, 3.1e-3] {
        let scale = int8_block_scale(amax);
        for q in -127i8..=127 {
            let x = int8_dequantize(q, scale);
            assert_eq!(int8_quantize(x, scale), q, "code {q} at scale {scale}");
        }
    }
}

#[test]
fn zero_and_subnormal_blocks_are_exact_or_flushed_finite() {
    // amax == 0: the block stores scale 0 and all-zero codes, and
    // dequantizes to exactly 0.0.
    let zeros = vec![0.0f32; INT8_BLOCK + 5];
    let v = ScaledBlockVec::from_f32(&zeros);
    assert!(v.scales().iter().all(|&s| s == 0.0));
    assert!(v.codes().iter().all(|&c| c == 0));
    assert!(v.to_f32().iter().all(|&x| x == 0.0));
    // Subnormal-only block: either representable within the error
    // bound or flushed to zero — never non-finite, and idempotent.
    let tiny = vec![f32::MIN_POSITIVE * 0.5, -f32::MIN_POSITIVE * 0.25, 0.0, 1e-41];
    let v = ScaledBlockVec::from_f32(&tiny);
    let back = v.to_f32();
    assert!(back.iter().all(|x| x.is_finite()));
    let again = ScaledBlockVec::from_f32(&back);
    assert_eq!(v.codes(), again.codes());
}

#[test]
fn int8_training_is_bitwise_deterministic() {
    // The determinism contract at int8: fixed block boundaries and the
    // deterministic scale rule make two identical runs bitwise equal —
    // losses and every exported parameter.
    let (losses_a, params_a) = adam_trajectory(Precision::Int8);
    let (losses_b, params_b) = adam_trajectory(Precision::Int8);
    assert_eq!(losses_a, losses_b, "repeated int8 training diverged bitwise");
    assert_eq!(params_a, params_b, "repeated int8 training produced different params");
}

#[test]
fn int8_loss_trajectory_tracks_bf16_within_tolerance() {
    // Acceptance: 24 int8 Adam steps within (generous) tolerance of
    // bf16.  Block quantization perturbs small-magnitude elements by up
    // to half the block's step, so the drift band is wider than
    // bf16-vs-f32 — but the run must stay finite and actually train.
    let (bf16_losses, _) = adam_trajectory(Precision::Bf16);
    let (int8_losses, _) = adam_trajectory(Precision::Int8);
    assert_eq!(int8_losses.len(), 24);
    assert!(int8_losses.iter().all(|l| l.is_finite()), "int8 produced non-finite loss");
    let rels: Vec<f32> = bf16_losses
        .iter()
        .zip(&int8_losses)
        .map(|(&b, &q)| (q - b).abs() / (1.0 + b.abs()))
        .collect();
    let mean_rel = rels.iter().sum::<f32>() / rels.len() as f32;
    let max_rel = rels.iter().copied().fold(0.0f32, f32::max);
    assert!(
        mean_rel < 0.35,
        "int8 trajectory drifted: mean rel {mean_rel:.4} (per-step {rels:?})"
    );
    assert!(max_rel < 1.2, "int8 trajectory diverged: max rel {max_rel:.4}");
    let first = int8_losses[0];
    let last = *int8_losses.last().unwrap();
    assert!(last < 0.9 * first, "int8 did not train: {first} -> {last}");
}

#[test]
fn int8_bytes_match_the_block_formula_exactly() {
    // Exact at-rest accounting: 1 byte per element + one 4-byte f32
    // scale per (started) 64-element block, at every store layer —
    // `storage_bytes`, `ScaledBlockVec` and `PackedVec` must agree.
    for n in [1usize, 5, 63, 64, 65, 129, 1000] {
        let expected = (n + 4 * n.div_ceil(INT8_BLOCK)) as u64;
        assert_eq!(Precision::Int8.storage_bytes(n as u64), expected, "formula at n={n}");
        let vals: Vec<f32> = (0..n).map(|i| (i as f32) * 0.21 - 3.0).collect();
        assert_eq!(ScaledBlockVec::from_f32(&vals).bytes(), expected, "ScaledBlockVec n={n}");
        assert_eq!(
            PackedVec::from_f32(Precision::Int8, &vals).bytes(),
            expected,
            "PackedVec n={n}"
        );
    }
}

#[test]
fn int8_model_and_adam_state_bytes_land_in_the_quarter_class() {
    // Measured end to end on real stores.  At paper width (d_hid 768,
    // block-aligned stores dominate) the aggregate sits at ~0.2656x
    // f32; the strict <= 0.27 acceptance gate on the 6-ENC config is
    // pinned by the U50 report test and the bench-matrix CI gate.  The
    // tiny config here carries a higher share of sub-block stores
    // (4-byte scale on a 36-element core), so its band is wider.
    let f32_params = NativeTrainer::random_init(&ModelConfig::paper(2), 40)
        .unwrap()
        .model
        .param_bytes();
    let int8_paper = NativeTrainer::random_init(&ModelConfig::paper(2), 40).unwrap();
    let int8_params = int8_paper.with_precision(Precision::Int8).model.param_bytes();
    let ratio = int8_params as f64 / f32_params as f64;
    assert!(
        (0.25..=0.27).contains(&ratio),
        "paper-config int8 param bytes ratio {ratio:.4} ({int8_params} / {f32_params})"
    );

    let (tokens, intents, slots) = two_examples();
    let state = |prec: Precision| {
        let mut t = NativeTrainer::random_init(&tiny_cfg(), 23).unwrap().with_optim(
            OptimConfig { kind: OptimKind::Adam, precision: prec, ..Default::default() },
        );
        t.train_step(&tokens, &intents, &slots, 1e-2).unwrap();
        (t.model.optim.allocated_state_elems(), t.model.optim.allocated_state_bytes())
    };
    let (f_elems, f_bytes) = state(Precision::F32);
    let (q_elems, q_bytes) = state(Precision::Int8);
    assert_eq!(f_elems, q_elems, "state element counts must not depend on precision");
    let state_ratio = q_bytes as f64 / f_bytes as f64;
    assert!(
        state_ratio > 0.25 && state_ratio < 0.30,
        "tiny-config int8 Adam state ratio {state_ratio:.4} ({q_bytes} / {f_bytes})"
    );
}

#[test]
fn nonfinite_step_backs_off_scale_and_skips_the_update() {
    let (tokens, intents, slots) = two_examples();
    let mut t = int8_trainer(25);
    for _ in 0..3 {
        t.train_step(&tokens, &intents, &slots, 1e-2).unwrap();
    }
    assert_eq!(t.model.scaler.scale(), LOSS_SCALE_INIT);
    assert_eq!(t.model.scaler.good_steps(), 3);
    let before = t.model.to_params();

    // Poison one gradient of a real backward pass — what an f16
    // overflow or a corrupt batch produces — and push it through the
    // guarded PU stage.
    let (loss, mut grads, _) = t.model.forward_backward(&tokens, &intents, &slots).unwrap();
    let poisoned = grads.keys().next().unwrap().clone();
    grads.get_mut(&poisoned).unwrap()[0] = f32::INFINITY;
    let applied = t.model.apply_grads_guarded(loss, &grads, 1e-2).unwrap();
    assert!(!applied, "non-finite step was applied");
    assert_eq!(t.model.to_params(), before, "skipped step still mutated parameters");
    assert_eq!(t.model.scaler.scale(), LOSS_SCALE_INIT / 2.0, "scale did not back off");
    assert_eq!(t.model.scaler.good_steps(), 0);
    assert_eq!(t.model.scaler.overflow_steps(), 1);

    // A NaN loss alone (finite gradients) must also be caught.
    let (_, clean_grads, _) = t.model.forward_backward(&tokens, &intents, &slots).unwrap();
    assert!(!t.model.apply_grads_guarded(f32::NAN, &clean_grads, 1e-2).unwrap());
    assert_eq!(t.model.to_params(), before);

    // The run keeps training normally afterwards.
    let out = t.train_step(&tokens, &intents, &slots, 1e-2).unwrap();
    assert!(out.loss.is_finite());
    assert_eq!(t.model.scaler.good_steps(), 1);
}

#[test]
fn loss_scaler_state_checkpoints_and_resumes_bitwise() {
    let (tokens, intents, slots) = two_examples();
    let mut a = int8_trainer(26);
    for _ in 0..2 {
        a.train_step(&tokens, &intents, &slots, 1e-2).unwrap();
    }
    // Force one overflow so the scaler is off its power-on default and
    // must ride along in the checkpoint.
    let (loss, mut grads, _) = a.model.forward_backward(&tokens, &intents, &slots).unwrap();
    grads.values_mut().next().unwrap()[0] = f32::NAN;
    assert!(!a.model.apply_grads_guarded(loss, &grads, 1e-2).unwrap());
    a.train_step(&tokens, &intents, &slots, 1e-2).unwrap();

    let dir = std::env::temp_dir().join(format!("int8_scaler_ckpt_{}", std::process::id()));
    a.save_checkpoint(&dir).unwrap();
    // Different seed on purpose: everything must come from the ckpt.
    let mut b = int8_trainer(99);
    b.load_checkpoint(&dir).unwrap();
    assert_eq!(b.model.scaler.scale(), a.model.scaler.scale(), "loss scale not restored");
    assert_eq!(b.model.scaler.good_steps(), a.model.scaler.good_steps());
    assert_eq!(a.model.to_params(), b.model.to_params(), "params differ after load");
    for step in 0..3 {
        a.train_step(&tokens, &intents, &slots, 1e-2).unwrap();
        b.train_step(&tokens, &intents, &slots, 1e-2).unwrap();
        assert_eq!(
            a.model.to_params(),
            b.model.to_params(),
            "resumed int8 trajectory diverged at step {step}"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn f16_spiked_batch_leaves_params_finite_and_resumes_bitwise() {
    // The half-precision overflow regression: before the guard, a
    // spiked batch wrote inf/NaN through the Adam moments into the f16
    // stores and the run never recovered.  Now the step is skipped,
    // every parameter stays finite, and the post-skip run checkpoints
    // and resumes bitwise.
    let (tokens, intents, slots) = two_examples();
    let mut t = NativeTrainer::random_init(&tiny_cfg(), 27).unwrap().with_optim(OptimConfig {
        kind: OptimKind::Adam,
        precision: Precision::F16,
        ..Default::default()
    });
    for _ in 0..2 {
        t.train_step(&tokens, &intents, &slots, 1e-2).unwrap();
    }
    let before = t.model.to_params();
    let scale_before = t.model.scaler.scale();

    // The spiked batch: a real backward whose gradients overflowed.
    let (loss, mut grads, _) = t.model.forward_backward(&tokens, &intents, &slots).unwrap();
    for g in grads.values_mut().take(2) {
        for v in g.iter_mut() {
            *v = f32::INFINITY;
        }
    }
    assert!(!t.model.apply_grads_guarded(loss, &grads, 1e-2).unwrap());
    assert_eq!(t.model.to_params(), before, "spiked f16 step mutated parameters");
    for (name, (_, data)) in t.model.to_params() {
        assert!(data.iter().all(|v| v.is_finite()), "'{name}' went non-finite");
    }
    assert_eq!(t.model.scaler.scale(), scale_before / 2.0);

    // Bitwise resume through a checkpoint after the skip.
    let dir = std::env::temp_dir().join(format!("f16_spike_ckpt_{}", std::process::id()));
    t.save_checkpoint(&dir).unwrap();
    let mut r = NativeTrainer::random_init(&tiny_cfg(), 13).unwrap().with_optim(OptimConfig {
        kind: OptimKind::Adam,
        precision: Precision::F16,
        ..Default::default()
    });
    r.load_checkpoint(&dir).unwrap();
    assert_eq!(r.model.scaler.scale(), t.model.scaler.scale());
    for _ in 0..2 {
        t.train_step(&tokens, &intents, &slots, 1e-2).unwrap();
        r.train_step(&tokens, &intents, &slots, 1e-2).unwrap();
        assert_eq!(t.model.to_params(), r.model.to_params(), "f16 resume diverged");
    }
    std::fs::remove_dir_all(&dir).ok();
}
