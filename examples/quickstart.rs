//! Quickstart: load the 2-encoder tensorized transformer, run a handful
//! of training steps on synthetic ATIS, and evaluate.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --features pjrt --example quickstart
//! ```
//!
//! (For the artifact-free rust-native path, see
//! `examples/train_native.rs`.)

#[cfg(feature = "pjrt")]
use tt_trainer::coordinator::Trainer;
#[cfg(feature = "pjrt")]
use tt_trainer::data::Dataset;
#[cfg(feature = "pjrt")]
use tt_trainer::runtime::{Engine, Manifest};

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!("quickstart needs the PJRT runtime: rebuild with --features pjrt");
    eprintln!("(or run the artifact-free example: cargo run --example train_native)");
    std::process::exit(2);
}

#[cfg(feature = "pjrt")]
fn main() -> anyhow::Result<()> {
    // 1. Load the AOT artifacts produced by `make artifacts`.
    let manifest = Manifest::load("artifacts")?;
    let spec = manifest.variant("tt_L2")?;
    println!(
        "model: {} | {} parameter arrays | {:.1}x compression ({:.1} MB -> {:.1} MB)",
        spec.name,
        spec.params.len(),
        spec.compression_ratio(),
        spec.dense_equivalent_scalars as f64 * 4.0 / 1e6,
        spec.size_mb(),
    );

    // 2. Compile on the PJRT CPU client and load the seeded init params.
    let engine = Engine::load(spec)?;

    // 3. Synthetic ATIS data (the real corpus is license-gated; the
    //    generator mirrors its joint intent+slot structure).
    let (train, test) = Dataset::paper_splits(&spec.config, 42);
    println!("data: {} train / {} test utterances", train.len(), test.len());

    // 4. Train a few steps with the paper's SGD setup (lr 4e-3, batch 1).
    let mut trainer = Trainer::new(engine, manifest.lr);
    let ev0 = trainer.evaluate(&test, Some(50))?;
    println!("before: intent acc {:.3} | slot acc {:.3}", ev0.intent_acc, ev0.slot_acc);
    for chunk in 0..5 {
        trainer.train_steps(&train, 20)?;
        println!(
            "step {:>3}: loss {:.4}",
            (chunk + 1) * 20,
            trainer.metrics.recent_loss(20)
        );
    }

    // 5. Evaluate again: the tensorized model learns.
    let ev1 = trainer.evaluate(&test, Some(50))?;
    println!("after:  intent acc {:.3} | slot acc {:.3}", ev1.intent_acc, ev1.slot_acc);
    println!(
        "host-side overhead: {:.1}% of step time",
        100.0 * trainer.metrics.host_overhead_frac()
    );
    Ok(())
}
