//! End-to-end driver (EXPERIMENTS.md §E2E): train the tensorized
//! transformer on synthetic ATIS through the full three-layer stack —
//! Pallas BTT kernels inside a JAX-lowered HLO train step, executed by
//! the rust coordinator via PJRT — and log the loss curve plus Table III
//! metrics.
//!
//! ```bash
//! cargo run --release --offline --example train_atis -- \
//!     --variant tt_L2 --steps 300 --eval-n 300
//! ```

#[cfg(feature = "pjrt")]
use tt_trainer::coordinator::Trainer;
#[cfg(feature = "pjrt")]
use tt_trainer::data::Dataset;
#[cfg(feature = "pjrt")]
use tt_trainer::runtime::{Engine, Manifest};
#[cfg(feature = "pjrt")]
use tt_trainer::util::cli::Args;

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!("train_atis needs the PJRT runtime: rebuild with --features pjrt");
    eprintln!("(or run the artifact-free example: cargo run --example train_native)");
    std::process::exit(2);
}

#[cfg(feature = "pjrt")]
fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let variant = args.get_or("variant", "tt_L2");
    let steps = args.get_usize("steps", 300);
    let eval_n = args.get_usize("eval-n", 300);
    let lr = args.get_f64("lr", 4e-3) as f32;
    let out_csv = args.get_or("out", "target/train_atis_loss.csv").to_string();

    let manifest = Manifest::load(args.get_or("artifacts", "artifacts"))?;
    let spec = manifest.variant(variant)?;
    println!("=== E2E: {variant} on synthetic ATIS ===");
    println!(
        "params: {} arrays / {} scalars ({:.1}x compression, {:.2} MB)",
        spec.params.len(),
        spec.n_param_scalars,
        spec.compression_ratio(),
        spec.size_mb()
    );

    let engine = Engine::load(spec)?;
    let (train, test) = Dataset::paper_splits(&spec.config, 42);
    let mut trainer = Trainer::new(engine, lr);

    let ev0 = trainer.evaluate(&test, Some(eval_n))?;
    println!(
        "step {:>5}: intent acc {:.3} | slot acc {:.3}  (untrained)",
        0, ev0.intent_acc, ev0.slot_acc
    );

    let report_every = (steps / 10).max(1);
    let mut done = 0usize;
    while done < steps {
        let chunk = report_every.min(steps - done);
        trainer.train_steps(&train, chunk)?;
        done += chunk;
        println!(
            "step {:>5}: loss {:.4} (mean of last {})",
            done,
            trainer.metrics.recent_loss(chunk),
            chunk
        );
    }

    let ev1 = trainer.evaluate(&test, Some(eval_n))?;
    trainer.metrics.record_eval(0, ev1.intent_acc, ev1.slot_acc);
    println!(
        "step {:>5}: intent acc {:.3} | slot acc {:.3}  (n={})",
        done, ev1.intent_acc, ev1.slot_acc, ev1.n
    );
    println!(
        "\ntiming: {:.1}s PJRT execute, {:.2}s host ({:.2}% coordinator overhead)",
        trainer.metrics.execute_secs,
        trainer.metrics.host_secs,
        100.0 * trainer.metrics.host_overhead_frac()
    );
    println!(
        "mean step latency: {:.1} ms",
        1e3 * (trainer.metrics.execute_secs + trainer.metrics.host_secs)
            / trainer.metrics.steps as f64
    );

    if let Some(parent) = std::path::Path::new(&out_csv).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(&out_csv, trainer.metrics.loss_csv())?;
    println!("loss curve -> {out_csv}");
    Ok(())
}
