//! Cost-model explorer: the paper's contraction-complexity study
//! (Table I forms, Fig. 6 comparison, Fig. 7 sweeps) over arbitrary
//! shapes from the command line.
//!
//! ```bash
//! cargo run --release --offline --example cost_explorer -- --rank 12 --seq 32
//! ```

use tt_trainer::costmodel::{compare_all, sweeps, LinearShape};
use tt_trainer::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let rank = args.get_usize("rank", 12);
    let seq = args.get_usize("seq", 32) as u64;

    let shape = LinearShape::uniform(&[8, 8, 12], &[12, 8, 8], rank);
    println!("=== Fig. 6 at rank {rank}, K = {seq} (768 x 768 layer) ===");
    println!(
        "{:<6} {:>14} {:>12} {:>12} {:>10} {:>10}",
        "method", "fwd muls", "act mem", "total mem", "comp-red", "mem-red"
    );
    for r in compare_all(&shape, seq) {
        println!(
            "{:<6} {:>14} {:>12} {:>12} {:>9.2}x {:>9.2}x",
            r.method, r.fwd_muls, r.memory_elems, r.total_memory,
            r.compute_reduction, r.memory_reduction
        );
    }

    println!("\n=== Fig. 7 (top): sequence-length sweep at rank {rank} ===");
    print!(
        "{}",
        sweeps::render_sweep(&sweeps::seq_len_sweep(rank, &sweeps::paper_seq_lens()), "seq")
    );

    println!("\n=== Fig. 7 (bottom): rank sweep at K = {seq} ===");
    print!(
        "{}",
        sweeps::render_sweep(&sweeps::rank_sweep(seq, &sweeps::paper_ranks()), "rank")
    );

    println!("\n=== Training complexity (Table I, x3 forward) ===");
    let f = LinearShape::training_factor();
    for r in compare_all(&shape, seq) {
        println!("{:<6} training muls ~ {}", r.method, r.fwd_muls * f);
    }
}
