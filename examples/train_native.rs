//! End-to-end **rust-native** training on synthetic ATIS — the paper's
//! on-device training story with no XLA, no Python and no AOT
//! artifacts: seeded init, FP -> BP -> PU loop (hand-derived backward
//! through the BTT contraction, fused SGD), evaluation, and export to
//! the native inference engine.
//!
//! ```bash
//! cargo run --release --example train_native -- --layers 2 --steps 300
//! cargo run --release --example train_native -- --optimizer adam --batch 8
//! ```

use tt_trainer::config::ModelConfig;
use tt_trainer::coordinator::Trainer;
use tt_trainer::data::Dataset;
use tt_trainer::inference::NativeModel;
use tt_trainer::optim::{OptimConfig, OptimKind};
use tt_trainer::train::NativeTrainer;
use tt_trainer::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let layers = args.get_usize("layers", 2);
    let steps = args.get_usize("steps", 300);
    let eval_n = args.get_usize("eval-n", 200);
    let seed = args.get_usize("seed", 42) as u64;
    let optim_defaults = OptimConfig::default();
    let optim = OptimConfig {
        kind: OptimKind::parse(args.get_or("optimizer", optim_defaults.kind.name()))?,
        batch_size: args.get_usize("batch", optim_defaults.batch_size).max(1),
        ..optim_defaults
    };
    let lr = args.get_f64("lr", optim.kind.default_lr() as f64) as f32;

    let cfg = ModelConfig::paper(layers);
    println!("=== native E2E: {layers}-ENC tensorized transformer ===");
    println!(
        "params: {} tensor-compressed scalars ({:.1}x vs dense)",
        cfg.tensor_params(),
        cfg.dense_equivalent_params() as f64 / cfg.tensor_params() as f64
    );

    println!(
        "optimizer {} | batch {} | lr {lr}",
        optim.kind.name(),
        optim.batch_size
    );
    let batch = optim.batch_size;
    let backend = NativeTrainer::random_init(&cfg, seed)?.with_optim(optim);
    let (train, test) = Dataset::paper_splits(&cfg, seed);
    let mut trainer = Trainer::with_batch(backend, lr, batch);

    let ev0 = trainer.evaluate(&test, Some(eval_n))?;
    println!(
        "step {:>5}: intent acc {:.3} | slot acc {:.3}  (untrained)",
        0, ev0.intent_acc, ev0.slot_acc
    );

    let report_every = (steps / 10).max(1);
    let mut done = 0usize;
    while done < steps {
        let chunk = report_every.min(steps - done);
        trainer.train_steps(&train, chunk)?;
        done += chunk;
        println!(
            "step {:>5}: loss {:.4} (mean of last {})",
            done,
            trainer.metrics.recent_loss(chunk),
            chunk
        );
    }

    let ev1 = trainer.evaluate(&test, Some(eval_n))?;
    println!(
        "step {:>5}: intent acc {:.3} | slot acc {:.3}  (n={})",
        done, ev1.intent_acc, ev1.slot_acc, ev1.n
    );
    println!(
        "timing: {:.2}s compute | {:.1} ms mean step | {:.0} tokens/s | {:.1}M muls/step (FP+BP, Eqs. 18-21)",
        trainer.metrics.execute_secs,
        1e3 * trainer.metrics.execute_secs / trainer.metrics.steps.max(1) as f64,
        trainer.metrics.tokens_per_sec(),
        trainer.backend.last_stats.muls as f64 / 1e6
    );

    // Export the trained parameters straight into the merged-factor
    // inference engine (the deployment path of serve_native).
    let infer = NativeModel::from_params(&cfg, &trainer.backend.model.to_params())?;
    let ex = &test.examples[0];
    let (intent, _slots) = infer.predict(&ex.tokens)?;
    println!("export check: inference engine predicts intent {intent} (gold {})", ex.intent);
    Ok(())
}
