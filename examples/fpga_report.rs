//! Full FPGA-simulator report: every hardware table and figure of the
//! paper's evaluation (Tables IV/V, Figs. 1, 12, 14, 15 plus the Fig. 9/10
//! scheduling studies).
//!
//! ```bash
//! cargo run --release --offline --example fpga_report
//! ```

use tt_trainer::config::ModelConfig;
use tt_trainer::costmodel::LinearShape;
use tt_trainer::fpga::{bram, energy, resources, schedule};

fn main() {
    println!("############ tt-trainer FPGA simulator report ############\n");

    println!("=== Fig. 9: QKV task rescheduling ===");
    let shape = LinearShape::paper();
    let (naive, resched) = schedule::fig9_compare(&shape, 32, 12);
    println!("  naive (6 MUL0 units):       {naive} cycles");
    println!("  rescheduled (2 MUL0 units): {resched} cycles  (same latency, 1/3 the units)\n");

    println!("=== Fig. 10: fused parallel BTT backprop buffer ===");
    println!("  unfused: {:>5} elements", schedule::fig10_buffer_elems(&shape, false));
    println!("  fused:   {:>5} elements = O(r)\n", schedule::fig10_buffer_elems(&shape, true));

    println!("=== Fig. 12: BRAM utilization efficiency ===");
    for layers in [2usize, 4, 6] {
        println!("  {layers}-ENC:");
        for a in bram::strategy_comparison(layers, 12) {
            println!(
                "    {:<20} {:>6} blocks  eta = {:.3}",
                a.strategy.name(),
                a.total_blocks,
                a.efficiency
            );
        }
    }

    println!("\n=== Fig. 14: BRAM vs rank (2-ENC, all TT cores) ===");
    for rank in [2usize, 4, 8, 12, 16, 24, 32, 48] {
        let allocs = bram::strategy_comparison(2, rank);
        println!(
            "  rank {rank:>2}: default {:>5} blocks | grouped {:>5} blocks | ideal {:>7.1}",
            allocs[0].total_blocks, allocs[3].total_blocks, allocs[3].ideal_blocks
        );
    }

    println!("\n=== Table IV: resource utilization ===");
    for layers in [2usize, 4, 6] {
        let r = resources::report(&ModelConfig::paper(layers));
        println!(
            "  {layers}-ENC: DSP {} ({:.0}%) | LUT {} ({:.0}%) | FF {} ({:.0}%) | BRAM {} ({:.0}%) | URAM {} ({:.0}%) | {:.2} W",
            r.dsp.used, r.dsp.pct(),
            r.lut.used, r.lut.pct(),
            r.ff.used, r.ff.pct(),
            r.bram.used, r.bram.pct(),
            r.uram.used, r.uram.pct(),
            r.total_power_w()
        );
    }

    println!("\n=== Table V: GPU vs FPGA end-to-end ===");
    print!("{}", energy::render_table_v(&energy::table_v()));

    println!("\n=== Fig. 1: headline memory / energy reductions ===");
    for p in energy::fig1() {
        println!(
            "  L{}: computing memory {:.0} -> {:.1} MB ({:.1}x) | energy {:.1} -> {:.1} kJ ({:.1}x)",
            p.n_layers,
            p.gpu_tt_memory_mb,
            p.fpga_memory_mb,
            p.gpu_tt_memory_mb / p.fpga_memory_mb,
            p.gpu_tt_energy_kj,
            p.fpga_energy_kj,
            p.gpu_tt_energy_kj / p.fpga_energy_kj
        );
    }

    println!("\n=== Fig. 15: computing memory breakdown ===");
    for p in energy::fig15() {
        println!(
            "  L{}: GPU total {:.0} | GPU reserved MM {:.0} | GPU reserved BTT {:.0} | FPGA {:.1} (MB)",
            p.n_layers, p.gpu_total_mb, p.gpu_reserved_matrix_mb, p.gpu_reserved_btt_mb, p.fpga_mb
        );
    }
}
