//! Deployment example: serve predictions with the **rust-native**
//! engine through the continuous-batching scheduler — no XLA/PJRT at
//! run time, just the TT/TTM tensor algebra (the paper's
//! edge-deployment story).
//!
//! The default build is fully native: load a checkpoint written by
//! `tt-trainer train --ckpt DIR` (or `cargo run --example train_native`),
//! stand up a [`tt_trainer::serve::Server`] over the shared engine, and
//! push the synthetic ATIS test split through it, reporting intent/slot
//! accuracy, per-request latency percentiles and batching statistics.
//!
//! ```bash
//! cargo run --release --offline -- train --steps 200 --ckpt ckpt_dir
//! cargo run --release --offline --example serve_native -- --ckpt ckpt_dir --serve-n 100
//! ```
//!
//! With no `--ckpt` the example serves the random init — the serving
//! path (batching, latency, determinism) is weight-value-independent.
//!
//! `--pjrt` (needs `--features pjrt` and `make artifacts`) instead
//! sources the parameters from the PJRT engine, fine-tuning
//! `--train-steps` first — the original offline/edge hand-off demo.

use std::sync::Arc;
use tt_trainer::config::ModelConfig;
use tt_trainer::coordinator::metrics::percentile;
use tt_trainer::data::{Dataset, INTENTS};
use tt_trainer::engine::NativeEngine;
use tt_trainer::serve::{ServeConfig, Server};
use tt_trainer::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let serve_n = args.get_usize("serve-n", 100);
    let engine = Arc::new(if args.has_flag("pjrt") {
        pjrt_engine(&args)?
    } else {
        native_engine(&args)?
    });
    let (_, test) = Dataset::paper_splits(&engine.cfg, args.get_usize("seed", 42) as u64);

    println!("[serve] scheduler up (continuous batching); serving {serve_n} requests");
    let server = Server::start(Arc::clone(&engine), ServeConfig::default())?;
    let handle = server.handle();
    let mut intent_hits = 0usize;
    let mut slot_hits = 0usize;
    let mut slot_total = 0usize;
    let mut lat_ms = Vec::with_capacity(serve_n);
    let mut max_batch = 0usize;
    let examples: Vec<_> = test.examples.iter().cycle().take(serve_n).collect();
    // Submit in windows so the scheduler sees genuine concurrency (and
    // coalesces), while staying under the admission bound.
    for window in examples.chunks(64) {
        let pending: Vec<_> = window
            .iter()
            .map(|ex| handle.submit(&ex.tokens).map_err(anyhow::Error::from))
            .collect::<anyhow::Result<_>>()?;
        for (ex, p) in window.iter().zip(pending) {
            let resp = p.wait()?;
            lat_ms.push(resp.latency.as_secs_f64() * 1e3);
            max_batch = max_batch.max(resp.batch_size);
            if resp.intent == ex.intent as usize {
                intent_hits += 1;
            }
            // Score the effective (untrimmed) positions the response covers.
            for (pred, &gold) in resp.slots.iter().zip(&ex.slots) {
                slot_hits += usize::from(*pred == gold as usize);
                slot_total += 1;
            }
        }
    }
    let stats = server.shutdown();
    println!(
        "[serve] intent acc {:.3} | slot acc {:.3} | latency p50 {:.2} ms | p95 {:.2} ms | p99 {:.2} ms",
        intent_hits as f64 / serve_n as f64,
        slot_hits as f64 / slot_total.max(1) as f64,
        percentile(&lat_ms, 50.0),
        percentile(&lat_ms, 95.0),
        percentile(&lat_ms, 99.0),
    );
    println!(
        "[serve] {} batches | mean batch {:.2} | max batch {} | rejected {}",
        stats.batches, stats.mean_batch, max_batch, stats.rejected
    );

    // Show a few predictions with their decoded intents.
    for ex in test.examples.iter().take(3) {
        let (intent, _) = engine.predict(&ex.tokens)?;
        println!(
            "[serve] predicted intent: {:<28} (gold: {})",
            INTENTS[intent], INTENTS[ex.intent as usize]
        );
    }
    Ok(())
}

/// Default source: a native checkpoint (`--ckpt` / `--init-ckpt`), or
/// the random init when neither is given.
fn native_engine(args: &Args) -> anyhow::Result<NativeEngine> {
    use tt_trainer::coordinator::TrainBackend;
    use tt_trainer::train::NativeTrainer;
    let layers = args.get_usize("layers", 2);
    let seed = args.get_usize("seed", 42) as u64;
    let cfg = ModelConfig::paper(layers);
    let mut trainer = NativeTrainer::random_init(&cfg, seed)?;
    if let Some(dir) = args.get("ckpt").or_else(|| args.get("init-ckpt")) {
        trainer.load_checkpoint(std::path::Path::new(dir))?;
        println!("[load] native checkpoint from {dir}");
    } else {
        println!(
            "[load] no --ckpt given: serving the random init \
             (train first: cargo run --release -- train --ckpt DIR)"
        );
    }
    trainer.model.engine()
}

/// `--pjrt`: source the parameters from the PJRT engine (the original
/// offline-train / edge-serve hand-off), fine-tuning a few steps first.
#[cfg(feature = "pjrt")]
fn pjrt_engine(args: &Args) -> anyhow::Result<NativeEngine> {
    use tt_trainer::inference::params_from_engine;
    use tt_trainer::runtime::{Engine, Manifest};
    let train_steps = args.get_usize("train-steps", 200);
    let manifest = Manifest::load(args.get_or("artifacts", "artifacts"))?;
    let spec = manifest.variant(args.get_or("variant", "tt_L2"))?;
    let cfg = spec.config.clone();
    let (train, _) = Dataset::paper_splits(&cfg, 42);
    println!("[offline] loading + training {train_steps} steps via PJRT ...");
    let mut engine = Engine::load(spec)?;
    for (i, ex) in train.examples.iter().cycle().take(train_steps).enumerate() {
        let out = engine.train_step(&ex.tokens, &[ex.intent], &ex.slots, 4e-3)?;
        if (i + 1) % 100 == 0 {
            println!("[offline] step {:>4}: loss {:.4}", i + 1, out.loss);
        }
    }
    // The PJRT runtime is dropped here; only rust-native code serves.
    NativeEngine::from_params(&cfg, &params_from_engine(&engine)?)
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_engine(_args: &Args) -> anyhow::Result<NativeEngine> {
    Err(anyhow::anyhow!(
        "--pjrt needs the `pjrt` feature (rebuild with --features pjrt); \
         the default native path needs no flag"
    ))
}
