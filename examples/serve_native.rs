//! Deployment example: serve predictions with the **rust-native**
//! inference engine — no XLA/PJRT at run time, just the TT/TTM tensor
//! algebra (the paper's edge-deployment story).
//!
//! Loads the trained-or-initial parameters through the PJRT engine once
//! (acting as the checkpoint reader), optionally fine-tunes a few steps,
//! exports to the native engine, and serves the synthetic ATIS test
//! split, reporting accuracy and per-request latency.
//!
//! ```bash
//! cargo run --release --offline --example serve_native -- --train-steps 200 --serve-n 100
//! ```

#[cfg(feature = "pjrt")]
use std::time::Instant;
#[cfg(feature = "pjrt")]
use tt_trainer::data::{Dataset, INTENTS};
#[cfg(feature = "pjrt")]
use tt_trainer::inference::{params_from_engine, NativeModel};
#[cfg(feature = "pjrt")]
use tt_trainer::runtime::{Engine, Manifest};
#[cfg(feature = "pjrt")]
use tt_trainer::util::cli::Args;

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!("serve_native's offline phase needs the PJRT runtime: rebuild with --features pjrt");
    eprintln!("(or train natively first: cargo run --example train_native)");
    std::process::exit(2);
}

#[cfg(feature = "pjrt")]
fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let train_steps = args.get_usize("train-steps", 200);
    let serve_n = args.get_usize("serve-n", 100);

    let manifest = Manifest::load(args.get_or("artifacts", "artifacts"))?;
    let spec = manifest.variant(args.get_or("variant", "tt_L2"))?;
    let cfg = spec.config.clone();
    let (train, test) = Dataset::paper_splits(&cfg, 42);

    // Phase 1 (offline): obtain trained parameters via the PJRT engine.
    println!("[offline] loading + training {train_steps} steps via PJRT ...");
    let mut engine = Engine::load(spec)?;
    for (i, ex) in train.examples.iter().cycle().take(train_steps).enumerate() {
        let out = engine.train_step(&ex.tokens, &[ex.intent], &ex.slots, 4e-3)?;
        if (i + 1) % 100 == 0 {
            println!("[offline] step {:>4}: loss {:.4}", i + 1, out.loss);
        }
    }

    // Phase 2 (edge): export to the native engine and serve.
    let model = NativeModel::from_params(&cfg, &params_from_engine(&engine)?)?;
    drop(engine); // the PJRT runtime is gone; only rust-native code below.

    println!(
        "[serve] native engine up ({} params arrays); serving {serve_n} requests",
        spec.params.len()
    );
    let mut intent_hits = 0usize;
    let mut lat = Vec::with_capacity(serve_n);
    for ex in test.examples.iter().take(serve_n) {
        let t0 = Instant::now();
        let (intent, _slots) = model.predict(&ex.tokens)?;
        lat.push(t0.elapsed().as_secs_f64());
        if intent == ex.intent as usize {
            intent_hits += 1;
        }
    }
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "[serve] intent acc {:.3} | latency p50 {:.2} ms | p95 {:.2} ms",
        intent_hits as f64 / serve_n as f64,
        lat[serve_n / 2] * 1e3,
        lat[(serve_n * 95 / 100).min(serve_n - 1)] * 1e3,
    );

    // Show a few predictions with their decoded intents.
    for ex in test.examples.iter().take(3) {
        let (intent, _) = model.predict(&ex.tokens)?;
        println!(
            "[serve] predicted intent: {:<28} (gold: {})",
            INTENTS[intent], INTENTS[ex.intent as usize]
        );
    }
    Ok(())
}
