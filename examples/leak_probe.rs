//! Leak bisect: run N steps in 3 modes, print RSS growth.
//! Needs the `pjrt` feature and `make artifacts`.
#[cfg(feature = "pjrt")]
use tt_trainer::data::Dataset;
#[cfg(feature = "pjrt")]
use tt_trainer::runtime::{Engine, Manifest};

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!("leak_probe needs the PJRT runtime: rebuild with --features pjrt");
    std::process::exit(2);
}

#[cfg(feature = "pjrt")]
fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/statm").unwrap();
    let pages: f64 = s.split_whitespace().nth(1).unwrap().parse().unwrap();
    pages * 4096.0 / 1e6
}

#[cfg(feature = "pjrt")]
fn main() -> anyhow::Result<()> {
    let mode = std::env::args().nth(1).unwrap_or("full".into());
    let n: usize = std::env::args().nth(2).unwrap_or("300".into()).parse()?;
    let m = Manifest::load("artifacts")?;
    let spec = m.variant("tt_L2")?;
    let mut engine = Engine::load(spec)?;
    let data = Dataset::synth(&spec.config, 1, 8);
    let ex = data.examples[0].clone();
    // warmup
    engine.train_step(&ex.tokens, &[ex.intent], &ex.slots, 4e-3)?;
    let r0 = rss_mb();
    match mode.as_str() {
        "full" => {
            for _ in 0..n {
                engine.train_step(&ex.tokens, &[ex.intent], &ex.slots, 4e-3)?;
            }
        }
        "eval" => {
            for _ in 0..n {
                engine.eval(&ex.tokens)?;
            }
        }
        _ => {}
    }
    let r1 = rss_mb();
    println!(
        "mode={mode} n={n}: rss {r0:.0} -> {r1:.0} MB (+{:.2} MB, {:.3} MB/step)",
        r1 - r0,
        (r1 - r0) / n as f64
    );
    Ok(())
}
