"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/seeds; assert_allclose against ref.py is the
core correctness signal for the compute hot path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import btt, ref, ttm

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def rand(rng, *shape):
    return jnp.asarray(rng.normal(0, 1, shape).astype("f4"))


# ---------------------------------------------------------------------------
# blocked matmul
# ---------------------------------------------------------------------------


@given(
    m=st.integers(1, 96),
    k=st.integers(1, 64),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31),
)
def test_matmul_matches_numpy(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = rand(rng, m, k)
    b = rand(rng, k, n)
    got = np.asarray(btt.matmul(a, b))
    want = np.asarray(a) @ np.asarray(b)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("block", [1, 8, 64, 128, 1024])
def test_matmul_block_sizes(block):
    rng = np.random.default_rng(0)
    a = rand(rng, 48, 32)
    b = rand(rng, 32, 40)
    got = np.asarray(btt.matmul(a, b, block_m=block, block_n=block))
    np.testing.assert_allclose(got, np.asarray(a) @ np.asarray(b), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# fused BTT apply
# ---------------------------------------------------------------------------


@given(
    k=st.integers(1, 64),
    n=st.sampled_from([12, 48, 768]),
    r=st.integers(1, 16),
    seed=st.integers(0, 2**31),
)
def test_btt_apply_matches_reference(k, n, r, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, k, n)
    z1t = rand(rng, n, r)
    z3t = rand(rng, r, n)
    bias = rand(rng, n)
    y, z2 = btt.btt_apply(x, z1t, z3t, bias)
    want_z2 = np.asarray(x) @ np.asarray(z1t)
    want_y = want_z2 @ np.asarray(z3t) + np.asarray(bias)
    np.testing.assert_allclose(np.asarray(z2), want_z2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y), want_y, rtol=2e-3, atol=2e-3)


def test_btt_apply_paper_shape():
    rng = np.random.default_rng(7)
    x = rand(rng, 32, 768)
    z1t = rand(rng, 768, 12)
    z3t = rand(rng, 12, 768)
    bias = rand(rng, 768)
    y, _ = btt.btt_apply(x, z1t, z3t, bias)
    want = (np.asarray(x) @ np.asarray(z1t)) @ np.asarray(z3t) + np.asarray(bias)
    np.testing.assert_allclose(np.asarray(y), want, rtol=2e-3, atol=2e-3)


@given(
    k=st.integers(1, 48),
    m=st.sampled_from([12, 768]),
    r=st.integers(1, 16),
    seed=st.integers(0, 2**31),
)
def test_btt_bwd_dx_matches_reference(k, m, r, seed):
    rng = np.random.default_rng(seed)
    dy = rand(rng, k, m)
    z3 = rand(rng, m, r)
    z1 = rand(rng, r, m)
    dx, dz2 = btt.btt_bwd_dx(dy, z3, z1)
    want_dz2 = np.asarray(dy) @ np.asarray(z3)
    want_dx = want_dz2 @ np.asarray(z1)
    np.testing.assert_allclose(np.asarray(dz2), want_dz2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(dx), want_dx, rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# TTM chain kernel
# ---------------------------------------------------------------------------


@given(
    k=st.integers(1, 40),
    m1=st.integers(1, 12),
    m2=st.integers(1, 8),
    m3=st.integers(1, 8),
    r1=st.integers(1, 16),
    r2=st.integers(1, 16),
    seed=st.integers(0, 2**31),
)
def test_ttm_chain_matches_einsum(k, m1, m2, m3, r1, r2, seed):
    rng = np.random.default_rng(seed)
    a1 = rand(rng, k, m1, r1)
    a2 = rand(rng, k, r1, m2, r2)
    a3 = rand(rng, k, r2, m3)
    got = np.asarray(ttm.ttm_chain(a1, a2, a3))
    want = np.einsum("kas,ksbt,ktc->kabc", a1, a2, a3).reshape(k, -1)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# ref.py self-consistency (the oracle itself)
# ---------------------------------------------------------------------------


def test_tt_to_dense_is_rank_chain():
    # Hand-check a d=1 "TT" (just two cores): W = G1 x G2.
    rng = np.random.default_rng(3)
    g1 = rand(rng, 1, 4, 3)
    g2 = rand(rng, 3, 5, 1)
    w = ref.tt_to_dense((g1, g2), d=1)
    want = np.asarray(g1)[0] @ np.asarray(g2)[..., 0]
    np.testing.assert_allclose(np.asarray(w), want, rtol=1e-5, atol=1e-6)


def test_merge_left_right_compose_to_dense():
    rng = np.random.default_rng(4)
    cores = tuple(
        rand(rng, *s)
        for s in [(1, 4, 3), (3, 3, 3), (3, 3, 3), (3, 4, 1)]
    )
    z3 = ref.merge_left_cores(cores[:2])
    z1 = ref.merge_right_cores(cores[2:])
    w = ref.tt_to_dense(cores, d=2)
    np.testing.assert_allclose(np.asarray(z3 @ z1), np.asarray(w), rtol=1e-5, atol=1e-5)


def test_ttm_to_dense_shape_and_lookup():
    rng = np.random.default_rng(5)
    cores = (rand(rng, 1, 4, 3, 4), rand(rng, 4, 4, 3, 4), rand(rng, 4, 3, 3, 1))
    table = ref.ttm_to_dense(cores)
    assert table.shape == (27, 48)
    # Row t must equal the explicit slice chain of Eq. 17.
    t = 14
    j = (t // 9, (t // 3) % 3, t % 3)
    row = np.einsum(
        "as,sbt,tc->abc",
        np.asarray(cores[0])[0, :, j[0], :],
        np.asarray(cores[1])[:, :, j[1], :],
        np.asarray(cores[2])[:, :, j[2], 0],
    ).reshape(-1)
    np.testing.assert_allclose(np.asarray(table[t]), row, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# attention kernel
# ---------------------------------------------------------------------------


@given(
    h=st.integers(1, 12),
    s=st.integers(2, 32),
    dh=st.sampled_from([8, 16, 64]),
    n_real=st.integers(1, 32),
    seed=st.integers(0, 2**31),
)
def test_fused_attention_matches_naive(h, s, dh, n_real, seed):
    from compile.kernels.attention import fused_attention

    rng = np.random.default_rng(seed)
    q = rand(rng, h, s, dh)
    k = rand(rng, h, s, dh)
    v = rand(rng, h, s, dh)
    mask = jnp.asarray((np.arange(s) < min(n_real, s)).astype("f4"))
    got = np.asarray(fused_attention(q, k, v, mask))
    want = np.asarray(ref.naive_attention(q, k, v, mask))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_attention_rows_are_convex_combinations():
    from compile.kernels.attention import fused_attention

    rng = np.random.default_rng(11)
    q = rand(rng, 2, 8, 16)
    k = rand(rng, 2, 8, 16)
    v = jnp.ones((2, 8, 16), jnp.float32)
    mask = jnp.ones((8,), jnp.float32)
    out = np.asarray(fused_attention(q, k, v, mask))
    # softmax rows sum to 1 -> attention over all-ones V returns ones.
    np.testing.assert_allclose(out, np.ones_like(out), rtol=1e-5, atol=1e-5)
