"""L2 layer correctness: TT linear / TTM embedding / attention custom
VJPs vs the dense oracles, forward and gradient."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import tt_layers as L
from compile.kernels import ref

settings.register_profile("layers", max_examples=15, deadline=None)
settings.load_profile("layers")


def make_tt(rng, m_modes, n_modes, rank):
    modes = list(m_modes) + list(n_modes)
    d2 = len(modes)
    ranks = [1] + [rank] * (d2 - 1) + [1]
    return tuple(
        jnp.asarray(rng.normal(0, 0.3, (ranks[k], modes[k], ranks[k + 1])).astype("f4"))
        for k in range(d2)
    )


def make_ttm(rng, hid_modes, vocab_modes, rank):
    d = len(hid_modes)
    ranks = [1] + [rank] * (d - 1) + [1]
    return tuple(
        jnp.asarray(
            rng.normal(0, 0.4, (ranks[k], hid_modes[k], vocab_modes[k], ranks[k + 1])).astype("f4")
        )
        for k in range(d)
    )


# ---------------------------------------------------------------------------
# TT linear
# ---------------------------------------------------------------------------


@given(
    k=st.integers(1, 40),
    rank=st.integers(1, 8),
    seed=st.integers(0, 2**31),
)
def test_tt_linear_forward_matches_dense(k, rank, seed):
    rng = np.random.default_rng(seed)
    cores = make_tt(rng, (4, 3), (3, 4), rank)
    x = jnp.asarray(rng.normal(0, 1, (k, 12)).astype("f4"))
    b = jnp.asarray(rng.normal(0, 1, (12,)).astype("f4"))
    w = ref.tt_to_dense(cores, 2)
    got = np.asarray(L.tt_linear(x, cores, b))
    want = np.asarray(ref.dense_linear(x, w, b))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@given(rank=st.integers(1, 6), seed=st.integers(0, 2**31))
def test_tt_linear_gradients_match_dense(rank, seed):
    rng = np.random.default_rng(seed)
    cores = make_tt(rng, (4, 3), (3, 4), rank)
    x = jnp.asarray(rng.normal(0, 1, (8, 12)).astype("f4"))
    b = jnp.asarray(rng.normal(0, 1, (12,)).astype("f4"))

    def loss_tt(x, cores, b):
        return jnp.sum(jnp.sin(L.tt_linear(x, cores, b)))

    def loss_dense(x, cores, b):
        return jnp.sum(jnp.sin(ref.dense_linear(x, ref.tt_to_dense(cores, 2), b)))

    g_tt = jax.grad(loss_tt, argnums=(0, 1, 2))(x, cores, b)
    g_dn = jax.grad(loss_dense, argnums=(0, 1, 2))(x, cores, b)
    for a, bb in zip(jax.tree.leaves(g_tt), jax.tree.leaves(g_dn)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb), rtol=5e-3, atol=5e-3)


def test_tt_linear_paper_shape():
    rng = np.random.default_rng(1)
    cores = make_tt(rng, (12, 8, 8), (8, 8, 12), 12)
    x = jnp.asarray(rng.normal(0, 1, (32, 768)).astype("f4"))
    b = jnp.zeros((768,), jnp.float32)
    w = ref.tt_to_dense(cores, 3)
    got = np.asarray(L.tt_linear(x, cores, b))
    want = np.asarray(x @ w.T)
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)


# ---------------------------------------------------------------------------
# TTM embedding
# ---------------------------------------------------------------------------


@given(rank=st.integers(1, 6), seed=st.integers(0, 2**31))
def test_ttm_embedding_matches_dense_lookup(rank, seed):
    rng = np.random.default_rng(seed)
    cores = make_ttm(rng, (4, 4, 3), (3, 3, 3), rank)
    toks = jnp.asarray(rng.integers(0, 27, (11,)).astype("i4"))
    table = ref.ttm_to_dense(cores)
    got = np.asarray(L.ttm_embedding(toks, cores, (3, 3, 3)))
    want = np.asarray(table[toks])
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@given(seed=st.integers(0, 2**31))
def test_ttm_embedding_grads_match_dense(seed):
    rng = np.random.default_rng(seed)
    cores = make_ttm(rng, (4, 4, 3), (3, 3, 3), 4)
    toks = jnp.asarray(rng.integers(0, 27, (9,)).astype("i4"))

    def loss_ttm(cores):
        return jnp.sum(jnp.cos(L.ttm_embedding(toks, cores, (3, 3, 3))))

    def loss_dense(cores):
        return jnp.sum(jnp.cos(ref.ttm_to_dense(cores)[toks]))

    g1 = jax.grad(loss_ttm)(cores)
    g2 = jax.grad(loss_dense)(cores)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-3, atol=5e-3)


def test_ttm_repeated_tokens_accumulate_grads():
    # The scatter-add in the backward pass must accumulate when the same
    # token appears twice (paper Eq. 12 over repeated indices).
    rng = np.random.default_rng(2)
    cores = make_ttm(rng, (4, 4, 3), (3, 3, 3), 4)
    t1 = jnp.asarray([5, 5], dtype="i4")
    t2 = jnp.asarray([5], dtype="i4")

    def s(cores, toks):
        return jnp.sum(L.ttm_embedding(toks, cores, (3, 3, 3)))

    g_twice = jax.grad(s)(cores, t1)
    g_once = jax.grad(s)(cores, t2)
    for a, b in zip(jax.tree.leaves(g_twice), jax.tree.leaves(g_once)):
        np.testing.assert_allclose(np.asarray(a), 2 * np.asarray(b), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# attention wrapper
# ---------------------------------------------------------------------------


def test_attention_grads_match_reference():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(0, 1, (4, 8, 16)).astype("f4"))
    k = jnp.asarray(rng.normal(0, 1, (4, 8, 16)).astype("f4"))
    v = jnp.asarray(rng.normal(0, 1, (4, 8, 16)).astype("f4"))
    mask = jnp.asarray([1, 1, 1, 1, 1, 1, 0, 0], dtype="f4")

    for arg in range(3):
        g1 = jax.grad(lambda *a: jnp.sum(jnp.tanh(L.attention(*a))), argnums=arg)(
            q, k, v, mask
        )
        g2 = jax.grad(
            lambda *a: jnp.sum(jnp.tanh(ref.naive_attention(*a))), argnums=arg
        )(q, k, v, mask)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=5e-3, atol=5e-3)


def test_attention_mask_blocks_padding():
    # Masked (PAD) key positions must not influence the output.
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.normal(0, 1, (2, 6, 8)).astype("f4"))
    k = jnp.asarray(rng.normal(0, 1, (2, 6, 8)).astype("f4"))
    v = jnp.asarray(rng.normal(0, 1, (2, 6, 8)).astype("f4"))
    mask = jnp.asarray([1, 1, 1, 0, 0, 0], dtype="f4")
    out1 = np.asarray(L.attention(q, k, v, mask))
    # Perturb the masked region of K/V: output must be unchanged.
    k2 = k.at[:, 3:, :].add(100.0)
    v2 = v.at[:, 3:, :].add(-50.0)
    out2 = np.asarray(L.attention(q, k2, v2, mask))
    np.testing.assert_allclose(out1, out2, rtol=1e-5, atol=1e-5)
