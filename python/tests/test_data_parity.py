"""Cross-language parity for the synthetic ATIS generator.

The constants below are pinned on BOTH sides (see the rust integration
test `rust/tests/data_parity.rs`): if either implementation drifts, one
of the two suites fails.
"""

import numpy as np

from compile.data import Generator, SplitMix64, Tokenizer, dataset


def test_splitmix_reference_sequence():
    r = SplitMix64(42)
    assert r.next_u64() == 13679457532755275413
    assert r.next_u64() == 2949826092126892291
    assert r.next_u64() == 5139283748462763858


def test_pinned_utterances_seed42():
    g = Generator(42)
    u1 = g.utterance()
    assert " ".join(u1.words) == "which airline operates flight two"
    assert u1.intent == 18
    assert u1.labels == [0, 0, 0, 0, 21]
    u2 = g.utterance()
    assert " ".join(u2.words) == "tell me about continental"
    assert u2.intent == 3
    assert u2.labels == [0, 0, 0, 15]
    u3 = g.utterance()
    assert " ".join(u3.words) == "i want to fly from new york to dallas in the noon"
    assert u3.intent == 0
    assert u3.labels == [0, 0, 0, 0, 0, 1, 2, 0, 3, 0, 0, 11]


def test_pinned_encoding_seed42():
    ds = dataset(42, 1)
    tokens, intent, slots = ds[0]
    assert tokens[:6] == [1, 193, 9, 135, 75, 183]
    assert intent == 18
    assert all(t == 0 for t in tokens[6:])


def test_vocab_size_under_cap():
    t = Tokenizer()
    assert len(t.word_to_id) + 3 <= 1000
    assert len(t.word_to_id) > 100


def test_dataset_examples_well_formed():
    for tokens, intent, slots in dataset(7, 100):
        assert len(tokens) == 32 and len(slots) == 32
        assert tokens[0] == 1  # CLS
        assert 0 <= intent < 26
        arr = np.array(tokens)
        assert arr.min() >= 0 and arr.max() < 1000
        for t, s in zip(tokens, slots):
            if t == 0:
                assert s == 0
