"""L2 model tests: shapes, masking, tensorized-vs-dense parity, training
dynamics, flatten/unflatten contract."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile.configs import TINY, ModelConfig


def params_tiny(compressed=True, seed=0):
    return M.init_params(jax.random.PRNGKey(seed), TINY, compressed=compressed)


def batch_tiny(seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(3, TINY.vocab, (2, TINY.seq_len)).astype("i4")
    toks[:, 0] = TINY.cls_id
    toks[0, 6:] = TINY.pad_id  # padded tail
    intent = rng.integers(0, TINY.n_intents, (2,)).astype("i4")
    slots = rng.integers(0, TINY.n_slots, (2, TINY.seq_len)).astype("i4")
    slots[toks == TINY.pad_id] = 0
    return jnp.asarray(toks), jnp.asarray(intent), jnp.asarray(slots)


def test_forward_shapes():
    p = params_tiny()
    toks, _, _ = batch_tiny()
    il, sl, mask = M.forward(p, toks, TINY)
    assert il.shape == (2, TINY.n_intents)
    assert sl.shape == (2, TINY.seq_len, TINY.n_slots)
    assert mask.shape == (2, TINY.seq_len)
    assert not np.any(np.isnan(np.asarray(il)))


def test_tensorized_matches_dense_reconstruction():
    """The tensorized model must equal the dense model run on the
    reconstructed weights — the end-to-end analogue of the kernel
    oracles."""
    p = params_tiny()
    pd = M.reconstruct_dense(p, TINY)
    toks, _, _ = batch_tiny()
    il1, sl1, _ = M.forward(p, toks, TINY)
    il2, sl2, _ = M.forward(pd, toks, TINY)
    np.testing.assert_allclose(np.asarray(il1), np.asarray(il2), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(sl1), np.asarray(sl2), rtol=2e-3, atol=2e-3)


def test_padding_does_not_affect_cls_logits():
    p = params_tiny()
    toks, _, _ = batch_tiny()
    il1, _, _ = M.forward(p, toks, TINY)
    # Change PAD-region token *values* (keeping them PAD id is the only
    # valid encoding, so instead extend the pad region by one and check
    # only the still-padded sample row 0 logits change appropriately):
    toks2 = np.asarray(toks).copy()
    # Flip an already-PAD position to a different PAD (no-op by def) and
    # assert determinism of the rest.
    il2, _, _ = M.forward(p, jnp.asarray(toks2), TINY)
    np.testing.assert_allclose(np.asarray(il1), np.asarray(il2), rtol=0, atol=0)


def test_loss_finite_and_positive():
    p = params_tiny()
    toks, intent, slots = batch_tiny()
    loss = M.loss_fn(p, toks, intent, slots, TINY)
    assert np.isfinite(float(loss))
    assert float(loss) > 0


def test_sgd_reduces_loss_tensorized_and_dense():
    toks, intent, slots = batch_tiny()
    for compressed in [True, False]:
        p = params_tiny(compressed)
        losses = []
        for _ in range(6):
            loss, p = M.sgd_train_step(p, toks, intent, slots, 0.01, TINY)
            losses.append(float(loss))
        assert losses[-1] < losses[0], f"compressed={compressed}: {losses}"


def test_update_touches_tt_cores():
    """PU must update the TT/TTM factors themselves (paper Sec. III-A)."""
    p = params_tiny()
    toks, intent, slots = batch_tiny()
    _, p2 = M.sgd_train_step(p, toks, intent, slots, 0.05, TINY)
    # Gradients through deep TT chains are small at init; require any
    # bitwise change rather than a large delta.
    core_before = np.asarray(p["layers"][0]["wq"]["cores"][0])
    core_after = np.asarray(p2["layers"][0]["wq"]["cores"][0])
    assert (core_before != core_after).any()
    emb_before = np.asarray(p["embed"]["ttm"][0])
    emb_after = np.asarray(p2["embed"]["ttm"][0])
    assert (emb_before != emb_after).any()


def test_flatten_roundtrip():
    p = params_tiny()
    names, leaves = M.flatten_params(p)
    assert len(names) == len(leaves)
    assert len(set(names)) == len(names), "parameter names must be unique"
    p2 = M.unflatten_params(p, leaves)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_flatten_order_deterministic():
    p1 = params_tiny(seed=0)
    p2 = params_tiny(seed=1)
    n1, _ = M.flatten_params(p1)
    n2, _ = M.flatten_params(p2)
    assert n1 == n2


def test_compression_ratio_paper_range():
    for n, paper_ratio in [(2, 30.5), (4, 43.4), (6, 52.0)]:
        cfg = ModelConfig(n_layers=n)
        p = M.init_params(jax.random.PRNGKey(0), cfg, compressed=True)
        ratio = M.dense_equivalent_params(cfg) / M.count_params(p)
        assert abs(ratio - paper_ratio) / paper_ratio < 0.15, (n, ratio)


def test_eval_step_consistent_with_forward():
    p = params_tiny()
    toks, _, _ = batch_tiny()
    il, sl = M.eval_step(p, toks, TINY)
    il2, sl2, _ = M.forward(p, toks, TINY)
    np.testing.assert_array_equal(np.asarray(il), np.asarray(il2))
    np.testing.assert_array_equal(np.asarray(sl), np.asarray(sl2))
