"""Fig. 13-style parity: the tensorized training dynamics must track a
dense-model reference on the same synthetic ATIS data.

The rust coordinator runs the same lowered HLO step, so passing here plus
the rust smoke test transfers the property to the accelerator path."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import data as D
from compile import model as M
from compile.configs import TINY


def tiny_batchify(n=24, seed=9):
    """Encode synthetic utterances at the tiny config (re-hash tokens and
    labels into the tiny vocab/label spaces to keep the test fast)."""
    examples = D.dataset(seed, n)
    out = []
    for tokens, intent, slots in examples:
        toks = np.array(tokens[: TINY.seq_len])
        # Re-map into tiny vocab, preserving PAD/CLS.
        toks = np.where(toks > 2, 3 + (toks - 3) % (TINY.vocab - 3), toks)
        sl = np.array(slots[: TINY.seq_len]) % TINY.n_slots
        sl[toks == 0] = 0
        out.append((
            jnp.asarray(toks[None].astype("i4")),
            jnp.asarray([intent % TINY.n_intents], dtype="i4"),
            jnp.asarray(sl[None].astype("i4")),
        ))
    return out


def run_curve(compressed: bool, steps: int = 24, lr: float = 0.01):
    params = M.init_params(jax.random.PRNGKey(0), TINY, compressed=compressed)
    batches = tiny_batchify(steps)
    losses = []
    for toks, intent, slots in batches:
        loss, params = M.sgd_train_step(params, toks, intent, slots, lr, TINY)
        losses.append(float(loss))
    return losses


def test_tensorized_curve_decreases():
    losses = run_curve(True)
    first = np.mean(losses[:6])
    last = np.mean(losses[-6:])
    assert last < first, f"no learning: {losses}"


def test_dense_curve_decreases():
    losses = run_curve(False)
    assert np.mean(losses[-6:]) < np.mean(losses[:6])


def test_curves_comparable():
    """Fig. 13's claim, scaled down: tensorized training matches the
    dense reference's convergence behaviour (same data, same lr).  We
    require the final tensorized loss to be within 50% of dense — the
    paper shows near-identical curves at full scale."""
    t = run_curve(True)
    d = run_curve(False)
    assert t[-1] < t[0] and d[-1] < d[0]
    # Both should reach the same order of loss reduction.
    red_t = t[0] - np.mean(t[-6:])
    red_d = d[0] - np.mean(d[-6:])
    assert red_t > 0.3 * red_d, f"tensor reduction {red_t} vs dense {red_d}"


def test_jitted_train_step_matches_eager():
    """The AOT artifact is a jitted train step — jitted and eager must
    agree (guards the lowering path numerics)."""
    params = M.init_params(jax.random.PRNGKey(1), TINY, compressed=True)
    toks, intent, slots = tiny_batchify(1)[0]

    eager_loss, eager_p = M.sgd_train_step(params, toks, intent, slots, 0.01, TINY)
    jitted = jax.jit(lambda p, t, i, s: M.sgd_train_step(p, t, i, s, 0.01, TINY))
    jit_loss, jit_p = jitted(params, toks, intent, slots)

    np.testing.assert_allclose(float(eager_loss), float(jit_loss), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(eager_p), jax.tree.leaves(jit_p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)
