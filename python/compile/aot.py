"""AOT compile path: lower train/eval steps to HLO *text* + manifest.

This is the only place python touches the pipeline; it runs at build time
(``make artifacts``) and never again.  For every model variant we emit:

  * ``artifacts/<variant>_train.hlo.txt``  — one fused SGD step:
      inputs  = [*params, tokens (B,S) i32, intent (B,) i32,
                 slots (B,S) i32, lr () f32]
      outputs = (loss () f32, *new_params)
  * ``artifacts/<variant>_eval.hlo.txt``   — inference:
      inputs  = [*params, tokens] ; outputs = (intent_logits, slot_logits)
  * ``artifacts/<variant>_init.npz``       — seeded initial parameters,
      keys ``%04d.<path>`` so zip order == argument order.
  * ``artifacts/manifest.json``            — parameter names/shapes/order,
      input specs, and model-config metadata for the rust runtime.

HLO **text** (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import functools
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import ModelConfig, TrainConfig, paper_configs

SEED = 20250711


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _specs_for(cfg: ModelConfig, leaves):
    param_specs = [jax.ShapeDtypeStruct(x.shape, x.dtype) for x in leaves]
    tok = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
    intent = jax.ShapeDtypeStruct((cfg.batch,), jnp.int32)
    slots = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
    lr = jax.ShapeDtypeStruct((), jnp.float32)
    return param_specs, tok, intent, slots, lr


def build_variant(name: str, cfg: ModelConfig, compressed: bool, out_dir: str):
    """Lower train + eval steps for one model variant; return manifest entry."""
    key = jax.random.PRNGKey(SEED)
    params = M.init_params(key, cfg, compressed=compressed)
    names, leaves = M.flatten_params(params)
    n_params = len(leaves)

    def train_fn(*args):
        p = M.unflatten_params(params, args[:n_params])
        tokens, intent, slots, lr = args[n_params:]
        loss, new_p = M.sgd_train_step(p, tokens, intent, slots, lr, cfg)
        _, new_leaves = M.flatten_params(new_p)
        return (loss, *new_leaves)

    def eval_fn(*args):
        p = M.unflatten_params(params, args[:n_params])
        tokens = args[n_params]
        return M.eval_step(p, tokens, cfg)

    param_specs, tok, intent, slots, lr = _specs_for(cfg, leaves)
    train_hlo = to_hlo_text(
        jax.jit(train_fn).lower(*param_specs, tok, intent, slots, lr)
    )
    eval_hlo = to_hlo_text(jax.jit(eval_fn).lower(*param_specs, tok))

    train_path = f"{name}_train.hlo.txt"
    eval_path = f"{name}_eval.hlo.txt"
    init_path = f"{name}_init.npz"
    with open(os.path.join(out_dir, train_path), "w") as f:
        f.write(train_hlo)
    with open(os.path.join(out_dir, eval_path), "w") as f:
        f.write(eval_hlo)
    np.savez(
        os.path.join(out_dir, init_path),
        **{f"{i:04d}.{n}": np.asarray(x) for i, (n, x) in enumerate(zip(names, leaves))},
    )

    tensor_params = M.count_params(params)
    dense_params = M.dense_equivalent_params(cfg)
    return {
        "name": name,
        "compressed": compressed,
        "train_hlo": train_path,
        "eval_hlo": eval_path,
        "init_npz": init_path,
        "train_hlo_sha256": hashlib.sha256(train_hlo.encode()).hexdigest(),
        "params": [
            {"name": n, "shape": list(x.shape), "dtype": str(x.dtype)}
            for n, x in zip(names, leaves)
        ],
        "n_params_arrays": n_params,
        "n_params_scalars": tensor_params,
        "dense_equivalent_scalars": dense_params,
        "compression_ratio": dense_params / tensor_params,
        "inputs": {
            "tokens": [cfg.batch, cfg.seq_len],
            "intent": [cfg.batch],
            "slots": [cfg.batch, cfg.seq_len],
        },
        "train_outputs": 1 + n_params,
        "config": {
            "n_layers": cfg.n_layers,
            "d_hid": cfg.d_hid,
            "n_heads": cfg.n_heads,
            "seq_len": cfg.seq_len,
            "batch": cfg.batch,
            "vocab": cfg.vocab,
            "n_intents": cfg.n_intents,
            "n_slots": cfg.n_slots,
            "tt_m": list(cfg.tt_m),
            "tt_n": list(cfg.tt_n),
            "tt_rank": cfg.tt_rank,
            "ttm_vocab_modes": list(cfg.ttm_vocab_modes),
            "ttm_hid_modes": list(cfg.ttm_hid_modes),
            "ttm_rank": cfg.ttm_rank,
            "pad_id": cfg.pad_id,
            "cls_id": cfg.cls_id,
            "unk_id": cfg.unk_id,
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--variants",
        default="tt_L2,tt_L4,tt_L6,mm_L2",
        help="comma list from {tt,mm}_L{2,4,6}",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfgs = paper_configs()
    entries = []
    for variant in args.variants.split(","):
        variant = variant.strip()
        kind, lname = variant.split("_")
        cfg = cfgs[lname]
        compressed = kind == "tt"
        print(f"[aot] lowering {variant} (compressed={compressed}) ...", flush=True)
        entries.append(build_variant(variant, cfg, compressed, args.out_dir))
        print(f"[aot] {variant}: {entries[-1]['n_params_arrays']} param arrays, "
              f"{entries[-1]['n_params_scalars']} scalars "
              f"({entries[-1]['compression_ratio']:.1f}x compression)", flush=True)

    manifest = {
        "seed": SEED,
        "train": {"lr": TrainConfig.lr, "epochs": TrainConfig.epochs},
        "variants": entries,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote manifest with {len(entries)} variants -> {args.out_dir}")


if __name__ == "__main__":
    main()
