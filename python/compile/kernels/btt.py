"""Layer-1 Pallas kernels for the bidirectional tensor-train (BTT) hot path.

The paper's BTT contraction (Sec. IV-B, Fig. 5 bottom) splits a TT-format
linear layer ``y = Wx`` into:

  * K-independent *core merges* (paper kernel MUL0): the ``d`` output-mode
    cores merge into ``Z3`` of shape ``(M, r)`` and the ``d`` input-mode
    cores merge into ``Z1`` of shape ``(r, N)``.  These run once per layer
    and are tiny (no dependence on the batch*seq dimension ``K``).
  * K-dependent *applies* (paper kernels MUL1 + MUL2):
    ``Z2 = X @ Z1^T`` of shape ``(K, r)`` and ``Y = Z2 @ Z3^T`` of shape
    ``(K, M)``.

This module implements the K-dependent applies as Pallas kernels.  The
fused kernel :func:`btt_apply` keeps the ``Z2`` intermediate in a VMEM
scratch accumulator so it never round-trips to HBM — the TPU analogue of
the paper's "fused parallel BTT" dataflow (Fig. 10), where fine-grained
contractions stream through a small on-chip buffer of size ``O(r)``.

All kernels are launched with ``interpret=True``: the CPU PJRT plugin used
by the rust runtime cannot execute Mosaic custom-calls, so the kernels are
lowered to plain HLO.  On a real TPU the same BlockSpecs tile ``X`` rows
into VMEM and feed the MXU with ``(block_k, N) x (N, r)`` and
``(block_k, r) x (r, M)`` matmuls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # hard requirement on CPU PJRT; see module docstring.


def _largest_divisor_leq(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is <= ``target`` (>= 1)."""
    target = max(1, min(n, target))
    for cand in range(target, 0, -1):
        if n % cand == 0:
            return cand
    return 1


# ---------------------------------------------------------------------------
# Blocked matmul kernel (generic building block, used by the backward pass)
# ---------------------------------------------------------------------------


def _matmul_kernel(a_ref, b_ref, o_ref):
    # One (block_m, block_n) output tile; the contraction dimension is kept
    # whole inside the block (it is <= d_hid = 768 floats ~ 3 KiB/row, well
    # within VMEM for the block sizes chosen below).
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def matmul(a: jax.Array, b: jax.Array, *, block_m: int = 128, block_n: int = 128):
    """``a @ b`` as a Pallas kernel with a 2-D output-tile grid.

    ``a``: (M, K), ``b``: (K, N) -> (M, N), all float32.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch: {a.shape} @ {b.shape}"
    bm = _largest_divisor_leq(m, block_m)
    bn = _largest_divisor_leq(n, block_n)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=INTERPRET,
    )(a, b)


# ---------------------------------------------------------------------------
# Fused BTT apply kernel (paper MUL1 + MUL2, fused per Fig. 10)
# ---------------------------------------------------------------------------


def _btt_apply_kernel(x_ref, z1t_ref, z3t_ref, bias_ref, o_ref, z2_ref):
    # x_ref:   (block_k, N)   one tile of input rows
    # z1t_ref: (N, r)         merged input-side cores, transposed
    # z3t_ref: (r, M)         merged output-side cores, transposed
    # bias_ref:(1, M)
    # o_ref:   (block_k, M)
    # z2_ref:  (block_k, r)
    #
    # Z2 is consumed by the second contraction inside the same kernel (the
    # fused dataflow of the paper's Fig. 10).  It is additionally written
    # out because training reuses it in backward propagation (the paper
    # stores these intermediates too — Sec. IV-A: "all of these
    # intermediate results need to be stored for reuse in back
    # propagation"); at (K, r) it is the *small* BTT intermediate.
    z2 = jnp.dot(x_ref[...], z1t_ref[...], preferred_element_type=jnp.float32)
    z2_ref[...] = z2
    o_ref[...] = (
        jnp.dot(z2, z3t_ref[...], preferred_element_type=jnp.float32)
        + bias_ref[...]
    )


@functools.partial(jax.jit, static_argnames=("block_k",))
def btt_apply(
    x: jax.Array,
    z1t: jax.Array,
    z3t: jax.Array,
    bias: jax.Array,
    *,
    block_k: int = 128,
):
    """Fused ``Y = (X @ Z1^T) @ Z3^T + bias`` over row tiles of ``X``.

    ``x``: (K, N) input rows, ``z1t``: (N, r), ``z3t``: (r, M),
    ``bias``: (M,) -> returns ``(y, z2)`` with ``y``: (K, M) and
    ``z2 = X @ Z1^T``: (K, r), the intermediate saved for backprop.
    """
    k, n = x.shape
    n2, r = z1t.shape
    r2, m = z3t.shape
    assert n == n2 and r == r2, (x.shape, z1t.shape, z3t.shape)
    assert bias.shape == (m,), bias.shape
    bk = _largest_divisor_leq(k, block_k)
    grid = (k // bk,)
    return pl.pallas_call(
        _btt_apply_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, n), lambda i: (i, 0)),
            pl.BlockSpec((n, r), lambda i: (0, 0)),
            pl.BlockSpec((r, m), lambda i: (0, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bk, m), lambda i: (i, 0)),
            pl.BlockSpec((bk, r), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, m), jnp.float32),
            jax.ShapeDtypeStruct((k, r), jnp.float32),
        ],
        interpret=INTERPRET,
    )(x, z1t, z3t, bias.reshape(1, m))


# ---------------------------------------------------------------------------
# Fused BTT backward kernel: dZ2 = dY @ Z3^T ; dX = dZ2 @ Z1  (MUL2+MUL3)
# ---------------------------------------------------------------------------


def _btt_bwd_dx_kernel(dy_ref, z3_ref, z1_ref, dx_ref, dz2_ref):
    # dy_ref: (block_k, M), z3_ref: (M, r), z1_ref: (r, N)
    # dx_ref: (block_k, N), dz2_ref: (block_k, r)
    dz2 = jnp.dot(dy_ref[...], z3_ref[...], preferred_element_type=jnp.float32)
    dz2_ref[...] = dz2
    dx_ref[...] = jnp.dot(dz2, z1_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_k",))
def btt_bwd_dx(dy: jax.Array, z3: jax.Array, z1: jax.Array, *, block_k: int = 128):
    """Fused activation-gradient contraction (paper Eq. 16 in BTT order).

    ``dy``: (K, M) output grad, ``z3``: (M, r) merged output cores,
    ``z1``: (r, N) merged input cores.
    Returns ``(dx, dz2)`` with ``dx``: (K, N) and ``dz2``: (K, r); ``dz2``
    is reused by the core-gradient contractions (Eqs. 10-11).
    """
    k, m = dy.shape
    m2, r = z3.shape
    r2, n = z1.shape
    assert m == m2 and r == r2
    bk = _largest_divisor_leq(k, block_k)
    grid = (k // bk,)
    dx, dz2 = pl.pallas_call(
        _btt_bwd_dx_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, m), lambda i: (i, 0)),
            pl.BlockSpec((m, r), lambda i: (0, 0)),
            pl.BlockSpec((r, n), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bk, n), lambda i: (i, 0)),
            pl.BlockSpec((bk, r), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, n), jnp.float32),
            jax.ShapeDtypeStruct((k, r), jnp.float32),
        ],
        interpret=INTERPRET,
    )(dy, z3, z1)
    return dx, dz2
