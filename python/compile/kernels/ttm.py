"""Layer-1 Pallas kernel for the TTM embedding rank contraction.

The TTM embedding lookup (paper Eq. 17) selects, for each token, one 2-D
slice ``F_k[:, :, j_k, :]`` from every TTM core and chains them over the
rank indices:

    y_{i1..id} = F_1[i_1, j_1] F_2[i_2, j_2] ... F_d[i_d, j_d]

The *gather* of the slices is data-dependent and stays in jnp (it lowers
to an HLO gather, the natural analogue of the paper's index-selected BRAM
reads).  The *rank-chain contraction* — the arithmetic hot spot — is done
here as a Pallas kernel over a grid of token tiles: for each token the
kernel performs the ``(m_<k, r) x (r, m_k * r')`` products entirely out of
on-chip blocks, mirroring the paper's rank-parallel BRAM access pattern
(Sec. V-C: "parallelism over the rank index across all tensor
contractions").
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .btt import INTERPRET, _largest_divisor_leq


def _ttm_chain_kernel(a1_ref, a2_ref, a3_ref, o_ref):
    # a1_ref: (bk, m1, r1)      gathered slices of core 1 (r0 == 1 squeezed)
    # a2_ref: (bk, r1, m2*r2)   gathered slices of core 2, flattened
    # a3_ref: (bk, r2, m3)      gathered slices of core 3 (r3 == 1 squeezed)
    # o_ref:  (bk, m1*m2*m3)
    bk, m1, r1 = a1_ref.shape
    _, _, m2r2 = a2_ref.shape
    _, r2, m3 = a3_ref.shape
    m2 = m2r2 // r2
    a1 = a1_ref[...]
    a2 = a2_ref[...]
    a3 = a3_ref[...]
    # (bk, m1, r1) x (bk, r1, m2*r2) -> (bk, m1, m2, r2)
    t = jnp.matmul(a1, a2, preferred_element_type=jnp.float32)
    t = t.reshape(bk, m1 * m2, r2)
    # (bk, m1*m2, r2) x (bk, r2, m3) -> (bk, m1*m2*m3)
    y = jnp.matmul(t, a3, preferred_element_type=jnp.float32)
    o_ref[...] = y.reshape(bk, m1 * m2 * m3)


@functools.partial(jax.jit, static_argnames=("block_k",))
def ttm_chain(a1: jax.Array, a2: jax.Array, a3: jax.Array, *, block_k: int = 64):
    """Chain-contract gathered TTM slices for a batch of tokens (d = 3).

    ``a1``: (K, m1, r1), ``a2``: (K, r1, m2, r2), ``a3``: (K, r2, m3)
    -> (K, m1*m2*m3) embedding rows.
    """
    k, m1, r1 = a1.shape
    _, r1b, m2, r2 = a2.shape
    _, r2b, m3 = a3.shape
    assert r1 == r1b and r2 == r2b, (a1.shape, a2.shape, a3.shape)
    a2f = a2.reshape(k, r1, m2 * r2)
    bk = _largest_divisor_leq(k, block_k)
    grid = (k // bk,)
    return pl.pallas_call(
        _ttm_chain_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, m1, r1), lambda i: (i, 0, 0)),
            pl.BlockSpec((bk, r1, m2 * r2), lambda i: (i, 0, 0)),
            pl.BlockSpec((bk, r2, m3), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bk, m1 * m2 * m3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((k, m1 * m2 * m3), jnp.float32),
        interpret=INTERPRET,
    )(a1, a2f, a3)
