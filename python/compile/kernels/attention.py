"""Layer-1 Pallas kernel for the attention score/apply matmuls.

The non-TT matrix products of the encoder — ``S = Q^T K / sqrt(d_k)``,
``P = softmax(S)`` and ``O = V P`` (paper Eq. 1; the paper's accelerator
implements these with dedicated MM kernels, Fig. 8) — are fused into a
single Pallas kernel per head.  At the paper's scale (seq = 32,
d_head = 64) the whole head fits in one VMEM block, so the kernel runs a
flash-attention-style single-block schedule: scores and the softmax
normalizer never leave on-chip memory.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .btt import INTERPRET


def _attn_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, scale: float):
    # All heads in one VMEM block: (H, S, Dh).  At the paper's scale
    # (12 x 32 x 64 f32 = 96 KiB per operand) the whole attention state
    # fits on-chip, so a single grid step avoids interpret-mode per-step
    # overhead (measured 3.4x faster than a per-head grid — see
    # EXPERIMENTS.md §Perf) while keeping the same fused dataflow.
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    mask = mask_ref[...]  # (S,) 1.0 for real tokens, 0.0 for PAD
    s = jnp.einsum("hqd,hkd->hqk", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    s = jnp.where(mask[None, None, :] > 0.5, s, -1e30)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = jnp.einsum("hqk,hkd->hqd", p, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=())
def fused_attention(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array):
    """Masked softmax attention over all heads in one fused kernel.

    ``q``/``k``/``v``: (H, S, Dh); ``mask``: (S,) with 1.0 = real token.
    Returns (H, S, Dh).
    """
    h, s, dh = q.shape
    scale = 1.0 / (dh**0.5)
    kern = functools.partial(_attn_kernel, scale=scale)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((h, s, dh), jnp.float32),
        interpret=INTERPRET,
    )(q, k, v, mask)
