"""Pure-jnp correctness oracles for the Pallas kernels and TT/TTM layers.

Every oracle reconstructs the *dense* object (full weight matrix / full
embedding table / naive attention) and computes the textbook result; the
pytest suite asserts the compressed BTT / TTM / fused paths match.
"""

from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp


def merge_left_cores(cores: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Merge output-mode TT cores G_1..G_d -> Z3 of shape (prod m_i, r_d).

    Each core G_k has shape (r_{k-1}, m_k, r_k); the chain is contracted
    left-to-right (paper kernel MUL0, left half of Fig. 5 bottom).
    """
    z = cores[0].reshape(cores[0].shape[1], cores[0].shape[2])  # r0 == 1
    for g in cores[1:]:
        r_prev, m_k, r_k = g.shape
        z = (z @ g.reshape(r_prev, m_k * r_k)).reshape(-1, r_k)
    return z  # (prod m_i, r_d)


def merge_right_cores(cores: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Merge input-mode TT cores G_{d+1}..G_{2d} -> Z1 of shape (r_d, prod n_i)."""
    last = cores[-1]
    z = last.reshape(last.shape[0], last.shape[1])  # r_{2d} == 1
    for g in reversed(cores[:-1]):
        r_prev, n_k, r_k = g.shape
        z = (g.reshape(r_prev * n_k, r_k) @ z).reshape(r_prev, -1)
    return z  # (r_d, prod n_i)


def tt_to_dense(cores: Sequence[jnp.ndarray], d: int) -> jnp.ndarray:
    """Reconstruct the dense (M, N) matrix from 2d TT cores (paper Eq. 7).

    The first ``d`` cores carry output modes m_i, the last ``d`` carry input
    modes n_i; element (i, j) of the matrix is the full rank-chain product.
    """
    z3 = merge_left_cores(cores[:d])  # (M, r_d)
    z1 = merge_right_cores(cores[d:])  # (r_d, N)
    return z3 @ z1


def ttm_to_dense(cores: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Reconstruct the dense (prod n_k [vocab], prod m_k [hidden]) embedding
    table from TTM cores F_k of shape (r_{k-1}, m_k, n_k, r_k) (paper Eq. 8).
    """
    # Chain over ranks, accumulating (m_1..m_k, n_1..n_k) free modes.
    z = cores[0][0]  # (m1, n1, r1); r0 == 1
    m_acc = cores[0].shape[1]
    n_acc = cores[0].shape[2]
    for f in cores[1:]:
        r_prev, m_k, n_k, r_k = f.shape
        z = z.reshape(m_acc * n_acc, r_prev) @ f.reshape(r_prev, m_k * n_k * r_k)
        z = z.reshape(m_acc, n_acc, m_k, n_k, r_k)
        z = z.transpose(0, 2, 1, 3, 4)
        m_acc *= m_k
        n_acc *= n_k
        z = z.reshape(m_acc, n_acc, r_k)
    z = z.reshape(m_acc, n_acc)  # (hidden, vocab)
    return z.T  # (vocab, hidden): row t is the embedding of token t


def dense_linear(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Row-major reference: x (K, N) @ w^T + b, w of shape (M, N)."""
    return x @ w.T + b


def naive_attention(q, k, v, mask):
    """(H, S, Dh) masked softmax attention, textbook version."""
    dh = q.shape[-1]
    s = jnp.einsum("hqd,hkd->hqk", q, k) / (dh**0.5)
    s = jnp.where(mask[None, None, :] > 0.5, s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", p, v)
