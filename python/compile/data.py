"""Synthetic ATIS-like data generator — python mirror of
``rust/src/data/grammar.rs`` + ``tokenizer.rs``.

MIRROR CONTRACT: template order, word-list order and RNG call sequence
match the rust implementation exactly; `python/tests/test_data_parity.py`
pins generated utterances, and the same constants are asserted on the
rust side.  The python copy exists for the Fig. 13 parity experiment
(python-reference training on the same corpus) and for pytest.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

MASK64 = (1 << 64) - 1


class SplitMix64:
    """Mirror of rust/src/util/rng.rs."""

    def __init__(self, seed: int):
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return z ^ (z >> 31)

    def below(self, bound: int) -> int:
        return (self.next_u64() * bound) >> 64


INTENTS = [
    "flight", "airfare", "ground_service", "airline", "abbreviation",
    "aircraft", "flight_time", "quantity", "distance", "city", "airport",
    "ground_fare", "capacity", "flight_no", "meal", "restriction",
    "cheapest", "flight+airfare", "airline+flight_no",
    "ground_service+ground_fare", "airfare+flight_time", "flight+airline",
    "flight_no+airline", "day_name", "period_of_day", "seat",
]

SLOT_TYPES = [
    "fromloc.city_name", "toloc.city_name", "depart_date.day_name",
    "depart_date.month_name", "depart_date.day_number",
    "depart_time.period_of_day", "arrive_time.period_of_day",
    "airline_name", "class_type", "meal_description", "flight_number",
    "aircraft_code", "airport_name", "city_name", "transport_type",
    "cost_relative", "round_trip", "fare_basis_code",
    "arrive_date.day_name", "stoploc.city_name",
]

CITIES = [
    "boston", "denver", "atlanta", "pittsburgh", "baltimore", "dallas",
    "oakland", "philadelphia", "washington", "charlotte", "milwaukee",
    "phoenix", "detroit", "chicago", "memphis", "seattle", "orlando",
    "cleveland", "nashville", "miami", "new york", "san francisco",
    "los angeles", "salt lake city",
]

AIRLINES = [
    "united airlines", "american airlines", "delta", "continental",
    "us air", "northwest", "lufthansa", "twa", "canadian airlines",
    "alaska airlines",
]

DAYS = ["monday", "tuesday", "wednesday", "thursday", "friday", "saturday", "sunday"]

MONTHS = [
    "january", "february", "march", "april", "may", "june", "july",
    "august", "september", "october", "november", "december",
]

DAY_NUMBERS = [
    "first", "second", "third", "fourth", "fifth", "sixth", "seventh",
    "eighth", "ninth", "tenth", "twentieth", "thirtieth",
]

PERIODS = ["morning", "afternoon", "evening", "night", "noon", "midnight"]

CLASSES = ["first class", "coach", "business class", "economy"]

MEALS = ["breakfast", "lunch", "dinner", "snack"]

FLIGHT_NUMBERS = ["one", "two", "three", "four", "five", "six", "seven", "eight"]

AIRCRAFT = ["boeing", "airbus", "dc ten", "md eighty", "jet", "turboprop"]

TRANSPORT = ["taxi", "limousine", "rental car", "bus"]

COST_REL = ["cheapest", "lowest", "most expensive"]

ROUND_TRIP = ["round trip", "one way"]

FARE_CODES = ["q", "qw", "f", "y", "h"]

WORD_LISTS = {
    "cities": CITIES,
    "airlines": AIRLINES,
    "days": DAYS,
    "months": MONTHS,
    "day_numbers": DAY_NUMBERS,
    "periods": PERIODS,
    "classes": CLASSES,
    "meals": MEALS,
    "flight_numbers": FLIGHT_NUMBERS,
    "aircraft": AIRCRAFT,
    "transport": TRANSPORT,
    "cost_rel": COST_REL,
    "round_trip": ROUND_TRIP,
    "fare_codes": FARE_CODES,
}


def L(w):  # literal part
    return ("lit", w)


def H(lst, slot):  # hole part
    return ("hole", lst, slot)


def templates() -> List[Tuple[int, list]]:
    """(intent, parts) in the exact rust order."""
    t: List[Tuple[int, list]] = []
    add = lambda intent, parts: t.append((intent, parts))
    # 0: flight
    add(0, [L("show"), L("me"), L("flights"), L("from"), H("cities", 0),
            L("to"), H("cities", 1), L("on"), H("days", 2)])
    add(0, [L("i"), L("want"), L("to"), L("fly"), L("from"), H("cities", 0),
            L("to"), H("cities", 1), L("in"), L("the"), H("periods", 5)])
    add(0, [L("list"), L("all"), L("flights"), L("leaving"), H("cities", 0),
            L("arriving"), L("in"), H("cities", 1), L("on"), H("months", 3),
            H("day_numbers", 4)])
    add(0, [L("are"), L("there"), H("round_trip", 16), L("flights"),
            L("between"), H("cities", 0), L("and"), H("cities", 1),
            L("with"), L("a"), L("stop"), L("in"), H("cities", 19)])
    # 1: airfare
    add(1, [L("what"), L("is"), L("the"), H("cost_rel", 15), L("fare"),
            L("from"), H("cities", 0), L("to"), H("cities", 1)])
    add(1, [L("how"), L("much"), L("does"), L("a"), H("classes", 8),
            L("ticket"), L("to"), H("cities", 1), L("cost")])
    add(1, [L("show"), L("fare"), L("code"), H("fare_codes", 17), L("for"),
            H("airlines", 7)])
    # 2: ground_service
    add(2, [L("what"), L("ground"), L("transportation"), L("is"),
            L("available"), L("in"), H("cities", 13)])
    add(2, [L("is"), L("there"), L("a"), H("transport", 14), L("service"),
            L("in"), H("cities", 13)])
    # 3: airline
    add(3, [L("which"), L("airlines"), L("fly"), L("from"), H("cities", 0),
            L("to"), H("cities", 1)])
    add(3, [L("tell"), L("me"), L("about"), H("airlines", 7)])
    # 4: abbreviation
    add(4, [L("what"), L("does"), L("fare"), L("code"), H("fare_codes", 17),
            L("mean")])
    # 5: aircraft
    add(5, [L("what"), L("type"), L("of"), L("aircraft"), L("is"),
            L("used"), L("flying"), L("from"), H("cities", 0), L("to"),
            H("cities", 1)])
    add(5, [L("show"), L("me"), L("all"), H("aircraft", 11), L("flights")])
    # 6: flight_time
    add(6, [L("what"), L("are"), L("the"), L("departure"), L("times"),
            L("from"), H("cities", 0), L("to"), H("cities", 1), L("in"),
            L("the"), H("periods", 5)])
    # 7: quantity
    add(7, [L("how"), L("many"), H("airlines", 7), L("flights"), L("leave"),
            H("cities", 0), L("each"), H("days", 2)])
    # 8: distance
    add(8, [L("how"), L("far"), L("is"), L("the"), L("airport"), L("from"),
            L("downtown"), H("cities", 13)])
    # 9: city
    add(9, [L("what"), L("city"), L("is"), L("served"), L("by"),
            H("airlines", 7)])
    # 10: airport
    add(10, [L("which"), L("airports"), L("are"), L("near"), H("cities", 13)])
    # 11: ground_fare
    add(11, [L("how"), L("much"), L("is"), L("a"), H("transport", 14),
             L("in"), H("cities", 13)])
    # 12: capacity
    add(12, [L("how"), L("many"), L("passengers"), L("fit"), L("on"),
             L("a"), H("aircraft", 11)])
    # 13: flight_no
    add(13, [L("what"), L("is"), L("the"), L("flight"), L("number"),
             L("from"), H("cities", 0), L("to"), H("cities", 1), L("on"),
             H("airlines", 7)])
    # 14: meal
    add(14, [L("is"), H("meals", 9), L("served"), L("on"), L("flight"),
             H("flight_numbers", 10)])
    # 15: restriction
    add(15, [L("what"), L("restrictions"), L("apply"), L("to"), L("the"),
             H("cost_rel", 15), L("fare")])
    # 16: cheapest
    add(16, [L("show"), L("the"), H("cost_rel", 15), H("round_trip", 16),
             L("ticket"), L("from"), H("cities", 0), L("to"), H("cities", 1)])
    # 17: flight+airfare
    add(17, [L("show"), L("flights"), L("and"), L("fares"), L("from"),
             H("cities", 0), L("to"), H("cities", 1), L("on"), H("days", 2)])
    # 18: airline+flight_no
    add(18, [L("which"), L("airline"), L("operates"), L("flight"),
             H("flight_numbers", 10)])
    # 19: ground_service+ground_fare
    add(19, [L("what"), L("is"), L("the"), L("cost"), L("of"), L("a"),
             H("transport", 14), L("from"), L("the"), L("airport"), L("in"),
             H("cities", 13)])
    # 20: airfare+flight_time
    add(20, [L("give"), L("me"), L("the"), L("fares"), L("and"),
             L("times"), L("for"), L("flights"), L("from"), H("cities", 0),
             L("to"), H("cities", 1), L("on"), H("days", 2), H("periods", 5)])
    # 21: flight+airline
    add(21, [L("list"), H("airlines", 7), L("flights"), L("from"),
             H("cities", 0), L("to"), H("cities", 1), L("arriving"),
             H("days", 18)])
    # 22: flight_no+airline
    add(22, [L("flight"), L("number"), L("and"), L("carrier"), L("from"),
             H("cities", 0), L("to"), H("cities", 1), L("please")])
    # 23: day_name
    add(23, [L("what"), L("day"), L("does"), L("flight"),
             H("flight_numbers", 10), L("leave")])
    # 24: period_of_day
    add(24, [L("do"), L("you"), L("have"), L("anything"), L("in"),
             L("the"), H("periods", 5), L("to"), H("cities", 1)])
    # 25: seat
    add(25, [L("i"), L("need"), L("a"), H("classes", 8), L("seat"),
             L("to"), H("cities", 1), L("on"), H("months", 3),
             H("day_numbers", 4)])
    # extra flight templates (class balance).
    add(0, [L("flights"), L("please"), L("from"), H("cities", 0), L("to"),
            H("cities", 1)])
    add(0, [H("airlines", 7), L("from"), H("cities", 0), L("to"),
            H("cities", 1), L("on"), H("days", 2), H("periods", 5)])
    return t


@dataclass
class Utterance:
    words: List[str]
    intent: int
    labels: List[int]


class Generator:
    """Mirror of rust Generator (same RNG call order)."""

    def __init__(self, seed: int):
        self.rng = SplitMix64(seed)
        self.templates = templates()

    def utterance(self) -> Utterance:
        ti = self.rng.below(len(self.templates))
        intent, parts = self.templates[ti]
        words: List[str] = []
        labels: List[int] = []
        for part in parts:
            if part[0] == "lit":
                words.append(part[1])
                labels.append(0)
            else:
                _, lst, slot = part
                choices = WORD_LISTS[lst]
                pick = choices[self.rng.below(len(choices))]
                for wi, w in enumerate(pick.split(" ")):
                    words.append(w)
                    labels.append(1 + 2 * slot if wi == 0 else 2 + 2 * slot)
        return Utterance(words, intent, labels)


class Tokenizer:
    """Mirror of rust Tokenizer: lexicographic vocab after PAD/CLS/UNK."""

    def __init__(self, vocab_cap: int = 1000, pad=0, cls=1, unk=2):
        words = set()
        for _, parts in templates():
            for part in parts:
                if part[0] == "lit":
                    words.add(part[1])
                else:
                    for w in WORD_LISTS[part[1]]:
                        for piece in w.split(" "):
                            words.add(piece)
        self.word_to_id = {}
        next_id = 3
        for w in sorted(words):
            if next_id >= vocab_cap:
                break
            self.word_to_id[w] = next_id
            next_id += 1
        self.pad, self.cls, self.unk = pad, cls, unk

    def id(self, word: str) -> int:
        return self.word_to_id.get(word, self.unk)

    def encode(self, utt: Utterance, seq_len: int):
        tokens = [self.pad] * seq_len
        slots = [0] * seq_len
        tokens[0] = self.cls
        for i, (w, l) in enumerate(zip(utt.words, utt.labels)):
            if i + 1 >= seq_len:
                break
            tokens[i + 1] = self.id(w)
            slots[i + 1] = l
        return tokens, utt.intent, slots


def dataset(seed: int, n: int, seq_len: int = 32):
    """Generate n encoded examples (mirror of rust Dataset::synth)."""
    tok = Tokenizer()
    gen = Generator(seed)
    out = []
    for _ in range(n):
        u = gen.utterance()
        out.append(tok.encode(u, seq_len))
    return out
