"""Model / training configurations mirroring the paper's Table II.

| layer          | format | matrix shape | tensor shape               | rank |
|----------------|--------|--------------|----------------------------|------|
| embedding      | TTM    | (1000, 768)  | ((10,10,10), (12,8,8))     | 30   |
| attention      | TT     | (768, 768)   | (12,8,8) x (8,8,12)        | 12   |
| feed-forward   | TT     | (768, 768)   | (12,8,8) x (8,8,12)        | 12   |
| classification | TT     | (768, 768)   | (12,8,8) x (8,8,12)        | 12   |

The final task-specific heads (intent / slot) are kept uncompressed, as in
the paper.  All shapes here are shared with the rust side through
``artifacts/manifest.json`` (emitted by :mod:`compile.aot`).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Configuration of the tensorized transformer (paper Fig. 2 / Table II)."""

    n_layers: int = 2
    d_hid: int = 768
    n_heads: int = 12
    seq_len: int = 32
    batch: int = 1
    vocab: int = 1000
    n_intents: int = 26
    n_slots: int = 129
    # TT factorization of every (768, 768) linear layer.
    tt_m: Tuple[int, ...] = (12, 8, 8)  # output modes, prod = 768
    tt_n: Tuple[int, ...] = (8, 8, 12)  # input modes,  prod = 768
    tt_rank: int = 12
    # TTM factorization of the (1000, 768) token-embedding table.
    ttm_vocab_modes: Tuple[int, ...] = (10, 10, 10)  # prod = 1000
    ttm_hid_modes: Tuple[int, ...] = (12, 8, 8)  # prod = 768
    ttm_rank: int = 30
    # Special token ids (shared with the rust-side tokenizer).
    pad_id: int = 0
    cls_id: int = 1
    unk_id: int = 2

    def __post_init__(self) -> None:
        assert math.prod(self.tt_m) == self.d_hid
        assert math.prod(self.tt_n) == self.d_hid
        assert math.prod(self.ttm_vocab_modes) == self.vocab
        assert math.prod(self.ttm_hid_modes) == self.d_hid
        assert self.d_hid % self.n_heads == 0

    @property
    def d_head(self) -> int:
        return self.d_hid // self.n_heads

    @property
    def tt_ranks(self) -> Tuple[int, ...]:
        """Full TT rank tuple (r_0, ..., r_2d) with r_0 = r_2d = 1."""
        d2 = len(self.tt_m) + len(self.tt_n)
        return (1,) + (self.tt_rank,) * (d2 - 1) + (1,)

    @property
    def ttm_ranks(self) -> Tuple[int, ...]:
        d = len(self.ttm_vocab_modes)
        return (1,) + (self.ttm_rank,) * (d - 1) + (1,)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """SGD hyper-parameters (paper Sec. VI-A)."""

    lr: float = 4e-3
    epochs: int = 40
    batch: int = 1


def paper_configs() -> dict:
    """The three evaluated model sizes (Tables III-V: 2/4/6 encoders)."""
    return {f"L{n}": ModelConfig(n_layers=n) for n in (2, 4, 6)}


# Tiny config used by the fast test-suite paths (keeps pytest quick while
# exercising the same code).
TINY = ModelConfig(
    n_layers=1,
    d_hid=48,
    n_heads=4,
    seq_len=8,
    vocab=27,
    n_intents=5,
    n_slots=7,
    tt_m=(4, 4, 3),
    tt_n=(3, 4, 4),
    tt_rank=3,
    ttm_vocab_modes=(3, 3, 3),
    ttm_hid_modes=(4, 4, 3),
    ttm_rank=4,
)
