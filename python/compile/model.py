"""Layer-2 model: the paper's tensorized transformer (Fig. 2) in JAX.

Architecture (paper Sec. II-A / Table II):

  * TTM token embedding (1000 x 768, modes (10,10,10)x(12,8,8), rank 30)
    + dense positional embedding + dense segment embedding.
  * N post-LN encoder blocks (Eq. 1): self-attention with TT-format
    W_q/W_k/W_v/W_o and an FFN with TT-format W_1/W_2 (all 768 x 768,
    modes (12,8,8)x(8,8,12), rank 12), GELU, residuals, LayerNorm.
  * TT-format classifier layer (768 x 768) with tanh, then uncompressed
    task heads: intent logits from the [CLS] position, slot logits from
    every position (ATIS joint intent + slot-filling, Sec. VI-B).

The same function also builds the *uncompressed* (matrix, "MM") baseline
used in Table III / Fig. 13 / Table V rows "GPU-Matrix" — switched by
``compressed=False`` — so the parity benches share one code path.

Parameters are a nested pytree; :func:`flatten_params` defines the
canonical flat ordering shared with the rust runtime via the manifest.
"""

from __future__ import annotations

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import tt_layers
from .configs import ModelConfig
from .kernels import ref as ref_kernels

Params = Dict


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def tt_core_shapes(cfg: ModelConfig) -> List[Tuple[int, int, int]]:
    """Shapes of the 2d TT cores of one (768, 768) linear layer."""
    modes = cfg.tt_m + cfg.tt_n
    ranks = cfg.tt_ranks
    return [(ranks[k], modes[k], ranks[k + 1]) for k in range(len(modes))]


def ttm_core_shapes(cfg: ModelConfig) -> List[Tuple[int, int, int, int]]:
    """Shapes of the d TTM cores of the token-embedding table."""
    ranks = cfg.ttm_ranks
    return [
        (ranks[k], cfg.ttm_hid_modes[k], cfg.ttm_vocab_modes[k], ranks[k + 1])
        for k in range(len(cfg.ttm_vocab_modes))
    ]


def _tt_init(key, cfg: ModelConfig, target_std: float):
    """Init TT cores so the reconstructed dense matrix has ~target_std.

    For i.i.d. zero-mean core entries, each dense element is a sum over
    ``prod(interior ranks)`` products of 2d entries, so
    ``var(W) = prod(r_i) * sigma^(2 * 2d)``.
    """
    shapes = tt_core_shapes(cfg)
    n_cores = len(shapes)
    rank_paths = math.prod(cfg.tt_ranks[1:-1])
    sigma = (target_std**2 / rank_paths) ** (1.0 / (2 * n_cores))
    keys = jax.random.split(key, n_cores)
    return tuple(
        sigma * jax.random.normal(k, s, jnp.float32) for k, s in zip(keys, shapes)
    )


def _ttm_init(key, cfg: ModelConfig, target_std: float):
    shapes = ttm_core_shapes(cfg)
    n_cores = len(shapes)
    rank_paths = math.prod(cfg.ttm_ranks[1:-1])
    sigma = (target_std**2 / rank_paths) ** (1.0 / (2 * n_cores))
    keys = jax.random.split(key, n_cores)
    return tuple(
        sigma * jax.random.normal(k, s, jnp.float32) for k, s in zip(keys, shapes)
    )


def _linear_params(key, cfg: ModelConfig, compressed: bool, target_std: float):
    if compressed:
        return {
            "cores": _tt_init(key, cfg, target_std),
            "bias": jnp.zeros((cfg.d_hid,), jnp.float32),
        }
    w = target_std * jax.random.normal(key, (cfg.d_hid, cfg.d_hid), jnp.float32)
    return {"w": w, "bias": jnp.zeros((cfg.d_hid,), jnp.float32)}


def init_params(key, cfg: ModelConfig, compressed: bool = True) -> Params:
    """Initialize the full parameter pytree (tensorized or matrix model)."""
    k_emb, k_pos, k_lay, k_cls, k_int, k_slt = jax.random.split(key, 6)
    lin_std = math.sqrt(2.0 / (2 * cfg.d_hid))
    if compressed:
        embed = {"ttm": _ttm_init(k_emb, cfg, 0.02)}
    else:
        embed = {
            "table": 0.02
            * jax.random.normal(k_emb, (cfg.vocab, cfg.d_hid), jnp.float32)
        }
    embed["pos"] = 0.02 * jax.random.normal(
        k_pos, (cfg.seq_len, cfg.d_hid), jnp.float32
    )
    layers = []
    for i in range(cfg.n_layers):
        ks = jax.random.split(jax.random.fold_in(k_lay, i), 6)
        layers.append(
            {
                "wq": _linear_params(ks[0], cfg, compressed, lin_std),
                "wk": _linear_params(ks[1], cfg, compressed, lin_std),
                "wv": _linear_params(ks[2], cfg, compressed, lin_std),
                "wo": _linear_params(ks[3], cfg, compressed, lin_std),
                "w1": _linear_params(ks[4], cfg, compressed, lin_std),
                "w2": _linear_params(ks[5], cfg, compressed, lin_std),
                "ln1": {
                    "g": jnp.ones((cfg.d_hid,), jnp.float32),
                    "b": jnp.zeros((cfg.d_hid,), jnp.float32),
                },
                "ln2": {
                    "g": jnp.ones((cfg.d_hid,), jnp.float32),
                    "b": jnp.zeros((cfg.d_hid,), jnp.float32),
                },
            }
        )
    heads_std = math.sqrt(1.0 / cfg.d_hid)
    return {
        "embed": embed,
        "layers": layers,
        "cls": {
            "pool": _linear_params(k_cls, cfg, compressed, lin_std),
            "intent_w": heads_std
            * jax.random.normal(k_int, (cfg.n_intents, cfg.d_hid), jnp.float32),
            "intent_b": jnp.zeros((cfg.n_intents,), jnp.float32),
            "slot_w": heads_std
            * jax.random.normal(k_slt, (cfg.n_slots, cfg.d_hid), jnp.float32),
            "slot_b": jnp.zeros((cfg.n_slots,), jnp.float32),
        },
    }


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def _linear(x, p):
    """Dispatch: TT (BTT contraction, Pallas) or dense rows ``x @ W^T + b``."""
    if "cores" in p:
        return tt_layers.tt_linear(x, p["cores"], p["bias"])
    return x @ p["w"].T + p["bias"]


def _layer_norm(x, g, b, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _encoder_block(x, mask, p, cfg: ModelConfig):
    """One post-LN encoder block (paper Eq. 1). ``x``: (S, H), ``mask``: (S,)."""
    s, h = x.shape
    q = _linear(x, p["wq"])  # (S, H)
    k = _linear(x, p["wk"])
    v = _linear(x, p["wv"])

    def heads(t):  # (S, H) -> (n_heads, S, d_head)
        return t.reshape(s, cfg.n_heads, cfg.d_head).transpose(1, 0, 2)

    attn = tt_layers.attention(heads(q), heads(k), heads(v), mask)
    attn = attn.transpose(1, 0, 2).reshape(s, h)
    x = _layer_norm(x + _linear(attn, p["wo"]), p["ln1"]["g"], p["ln1"]["b"])
    ffn = _linear(jax.nn.gelu(_linear(x, p["w1"])), p["w2"])
    return _layer_norm(x + ffn, p["ln2"]["g"], p["ln2"]["b"])


def forward(params: Params, tokens: jnp.ndarray, cfg: ModelConfig):
    """Run the transformer on a batch.

    ``tokens``: (B, S) int32, position 0 holds [CLS], ``pad_id`` marks
    padding.  Returns ``(intent_logits (B, n_intents),
    slot_logits (B, S, n_slots), mask (B, S))``.
    """
    b, s = tokens.shape
    flat = tokens.reshape(-1)
    if "ttm" in params["embed"]:
        emb = tt_layers.ttm_embedding(
            flat, params["embed"]["ttm"], cfg.ttm_vocab_modes
        )
    else:
        emb = params["embed"]["table"][flat]
    x = emb.reshape(b, s, cfg.d_hid) + params["embed"]["pos"][None]
    mask = (tokens != cfg.pad_id).astype(jnp.float32)  # (B, S)

    def run_one(xb, mb):
        for layer in params["layers"]:
            xb = _encoder_block(xb, mb, layer, cfg)
        return xb

    # The paper trains with batch 1; the loop below vectorizes over the
    # batch without changing the per-sample BTT dataflow.
    xs = [run_one(x[i], mask[i]) for i in range(b)]
    x = jnp.stack(xs)  # (B, S, H)

    pooled = jnp.tanh(_linear(x.reshape(b * s, cfg.d_hid), params["cls"]["pool"]))
    pooled = pooled.reshape(b, s, cfg.d_hid)
    cls_vec = pooled[:, 0, :]  # [CLS]
    intent_logits = cls_vec @ params["cls"]["intent_w"].T + params["cls"]["intent_b"]
    slot_logits = pooled @ params["cls"]["slot_w"].T + params["cls"]["slot_b"]
    return intent_logits, slot_logits, mask


# ---------------------------------------------------------------------------
# Loss / train / eval steps
# ---------------------------------------------------------------------------


def _cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]


def loss_fn(params, tokens, intent, slots, cfg: ModelConfig):
    """Joint intent + slot-filling loss (both cross-entropy, slots masked)."""
    intent_logits, slot_logits, mask = forward(params, tokens, cfg)
    li = jnp.mean(_cross_entropy(intent_logits, intent))
    ls_all = _cross_entropy(slot_logits, slots)  # (B, S)
    # position 0 is [CLS]: labeled O (class 0) by the data generator and
    # included; PAD positions are masked out.
    ls = jnp.sum(ls_all * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return li + ls


def sgd_train_step(params, tokens, intent, slots, lr, cfg: ModelConfig):
    """One SGD step (paper stage FP -> BP -> PU, Sec. III-A).

    Returns ``(loss, new_params)``; the parameter update
    ``G_k <- G_k - lr * G_k'`` happens on TT/TTM factors directly.
    """
    loss, grads = jax.value_and_grad(loss_fn)(params, tokens, intent, slots, cfg)
    new_params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
    return loss, new_params


def eval_step(params, tokens, cfg: ModelConfig):
    """Inference: returns (intent_logits, slot_logits)."""
    intent_logits, slot_logits, _ = forward(params, tokens, cfg)
    return intent_logits, slot_logits


# ---------------------------------------------------------------------------
# Flattening (canonical parameter order shared with rust via the manifest)
# ---------------------------------------------------------------------------


def flatten_params(params: Params):
    """Flatten to ``(names, leaves)`` with deterministic path-based names."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    names, leaves = [], []
    for path, leaf in flat:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        names.append(".".join(parts))
        leaves.append(leaf)
    return names, leaves


def unflatten_params(params_template: Params, leaves):
    treedef = jax.tree_util.tree_structure(params_template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def count_params(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def dense_equivalent_params(cfg: ModelConfig) -> int:
    """Parameter count of the uncompressed model (Table III 'Size' column)."""
    per_lin = cfg.d_hid * cfg.d_hid + cfg.d_hid
    per_layer = 6 * per_lin + 4 * cfg.d_hid
    return (
        cfg.vocab * cfg.d_hid
        + cfg.seq_len * cfg.d_hid
        + cfg.n_layers * per_layer
        + per_lin
        + cfg.n_intents * (cfg.d_hid + 1)
        + cfg.n_slots * (cfg.d_hid + 1)
    )


def reconstruct_dense(params: Params, cfg: ModelConfig) -> Params:
    """Expand a tensorized parameter tree into the equivalent dense tree.

    Used by parity tests: the dense model run on the reconstructed weights
    must produce identical logits to the tensorized model.
    """

    def conv_linear(p):
        if "cores" in p:
            d = len(p["cores"]) // 2
            return {
                "w": ref_kernels.tt_to_dense(p["cores"], d),
                "bias": p["bias"],
            }
        return p

    out = {
        "embed": {"pos": params["embed"]["pos"]},
        "layers": [],
        "cls": dict(params["cls"]),
    }
    if "ttm" in params["embed"]:
        out["embed"]["table"] = ref_kernels.ttm_to_dense(params["embed"]["ttm"])
    else:
        out["embed"]["table"] = params["embed"]["table"]
    for layer in params["layers"]:
        new = {}
        for k, v in layer.items():
            new[k] = conv_linear(v) if k.startswith("w") else v
        out["layers"].append(new)
    out["cls"]["pool"] = conv_linear(params["cls"]["pool"])
    return out
