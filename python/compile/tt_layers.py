"""Layer-2 tensorized layers: TT linear and TTM embedding with custom VJPs.

The forward/backward contraction *order* is the paper's contribution
(Sec. IV-B, bidirectional tensor-train / BTT):

  forward   Z3 = merge(G_1..G_d)      (M, r)   K-independent  (MUL0)
            Z1 = merge(G_{d+1}..G_2d) (r, N)   K-independent  (MUL0)
            Z2 = X  Z1^T              (K, r)   Pallas          (MUL1)
            Y  = Z2 Z3^T + b          (K, M)   Pallas, fused   (MUL2)

  backward  dZ2 = dY Z3 ; dX = dZ2 Z1 (Eq. 16 in BTT order)    Pallas fused
            dZ3 = dY^T Z2 ; dZ1 = dZ2^T X                      Pallas
            core grads by back-propagating through the merges
            (Eqs. 10-11: eliminate G_k from the network, contract the rest)

The custom_vjp pins this order — autodiff of a naive right-to-left
contraction would re-introduce the K-dependent intermediates the paper
eliminates.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import btt
from .kernels import ref as ref_kernels
from .kernels import ttm as ttm_kernels
from .kernels.attention import fused_attention


# ---------------------------------------------------------------------------
# TT linear layer
# ---------------------------------------------------------------------------


def merge_left(*cores: jnp.ndarray) -> jnp.ndarray:
    """Z3 = G_1 x ... x G_d reshaped to (prod m_i, r_d)."""
    return ref_kernels.merge_left_cores(cores)


def merge_right(*cores: jnp.ndarray) -> jnp.ndarray:
    """Z1 = G_{d+1} x ... x G_{2d} reshaped to (r_d, prod n_i)."""
    return ref_kernels.merge_right_cores(cores)


@jax.custom_vjp
def tt_linear(x: jnp.ndarray, cores: Tuple[jnp.ndarray, ...], bias: jnp.ndarray):
    """``y = W x + b`` with ``W`` in TT format, computed in BTT order.

    ``x``: (K, N) rows; ``cores``: 2d TT cores, first d carrying output
    modes; ``bias``: (M,).  Returns (K, M).
    """
    d = len(cores) // 2
    z3 = merge_left(*cores[:d])
    z1 = merge_right(*cores[d:])
    y, _ = btt.btt_apply(x, z1.T, z3.T, bias)
    return y


def _tt_linear_fwd(x, cores, bias):
    d = len(cores) // 2
    z3 = merge_left(*cores[:d])
    z1 = merge_right(*cores[d:])
    y, z2 = btt.btt_apply(x, z1.T, z3.T, bias)
    return y, (x, cores, z1, z3, z2)


def _tt_linear_bwd(res, dy):
    x, cores, z1, z3, z2 = res
    d = len(cores) // 2
    # Fused activation gradient (paper Eq. 16 in BTT order): the (K, r)
    # intermediate dZ2 is produced and consumed in one Pallas kernel and
    # reused below for the core gradients.
    dx, dz2 = btt.btt_bwd_dx(dy, z3, z1)
    db = jnp.sum(dy, axis=0)
    # Merged-core gradients (K-dependent part of Eqs. 10-11).  These are
    # rank-thin (M x r / r x N) products — XLA-native dots beat an extra
    # interpret-mode kernel launch by ~5x here (EXPERIMENTS.md §Perf);
    # the genuinely hot K-wide contractions above stay in Pallas.
    dz3 = dy.T @ z2  # (M, r)
    dz1 = dz2.T @ x  # (r, N)
    # Distribute into individual cores: eliminate G_k from the merge chain
    # and contract the remaining nodes (K-independent part of Eqs. 10-11).
    _, vjp_left = jax.vjp(merge_left, *cores[:d])
    _, vjp_right = jax.vjp(merge_right, *cores[d:])
    dcores = tuple(vjp_left(dz3)) + tuple(vjp_right(dz1))
    return dx, dcores, db


tt_linear.defvjp(_tt_linear_fwd, _tt_linear_bwd)


# ---------------------------------------------------------------------------
# TTM embedding table
# ---------------------------------------------------------------------------


def _token_digits(tokens: jnp.ndarray, vocab_modes: Sequence[int]):
    """Mixed-radix decomposition of token ids into per-core indices j_k."""
    digits = []
    rem = tokens
    for base in reversed(vocab_modes):
        digits.append(rem % base)
        rem = rem // base
    return tuple(reversed(digits))  # j_1 .. j_d, most-significant first


def _gather_slices(cores, digits):
    """Select F_k[:, :, j_k, :] for every token -> per-token slice stacks."""
    f1, f2, f3 = cores
    j1, j2, j3 = digits
    # f1: (1, m1, n1, r1)  -> a1: (K, m1, r1)
    a1 = jnp.take(f1[0], j1, axis=1).transpose(1, 0, 2)
    # f2: (r1, m2, n2, r2) -> a2: (K, r1, m2, r2)
    a2 = jnp.take(f2, j2, axis=2).transpose(2, 0, 1, 3)
    # f3: (r2, m3, n3, 1)  -> a3: (K, r2, m3)
    a3 = jnp.take(f3[..., 0], j3, axis=2).transpose(2, 0, 1)
    return a1, a2, a3


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def ttm_embedding(tokens: jnp.ndarray, cores: Tuple[jnp.ndarray, ...],
                  vocab_modes: Tuple[int, ...]):
    """TTM embedding lookup (paper Eq. 17), d = 3.

    ``tokens``: (K,) int32 ids; ``cores``: 3 TTM cores F_k of shape
    (r_{k-1}, m_k, n_k, r_k).  Returns (K, prod m_k) rows.
    """
    digits = _token_digits(tokens, vocab_modes)
    a1, a2, a3 = _gather_slices(cores, digits)
    return ttm_kernels.ttm_chain(a1, a2, a3)


def _ttm_embedding_fwd(tokens, cores, vocab_modes):
    digits = _token_digits(tokens, vocab_modes)
    a1, a2, a3 = _gather_slices(cores, digits)
    y = ttm_kernels.ttm_chain(a1, a2, a3)
    return y, (digits, a1, a2, a3, tuple(c.shape for c in cores))


def _ttm_embedding_bwd(vocab_modes, res, dy):
    del vocab_modes  # static; digits were computed in fwd
    digits, a1, a2, a3, core_shapes = res
    j1, j2, j3 = digits
    k, m1, r1 = a1.shape
    _, _, m2, r2 = a2.shape
    _, _, m3 = a3.shape
    dy4 = dy.reshape(k, m1, m2, m3)
    # Forward: y_{k,abc} = sum_{s,t} a1[k,a,s] a2[k,s,b,t] a3[k,t,c]
    b_mid = jnp.einsum("ksbt,ktc->ksbc", a2, a3)  # (K, r1, m2, m3)
    da1 = jnp.einsum("kabc,ksbc->kas", dy4, b_mid)
    db_mid = jnp.einsum("kabc,kas->ksbc", dy4, a1)
    da2 = jnp.einsum("ksbc,ktc->ksbt", db_mid, a3)
    da3 = jnp.einsum("ksbc,ksbt->ktc", db_mid, a2)
    # Scatter-add the per-token slice gradients back into the cores
    # (paper Eq. 12: only the selected slices receive gradient).
    # Indexing-shape rules: a lone advanced index keeps the K axis in
    # place; a scalar+array (non-contiguous) pair moves K to the front.
    df1 = jnp.zeros(core_shapes[0], jnp.float32)
    df1 = df1.at[0, :, j1, :].add(da1)  # (K, m1, r1): 0 + j1 -> K first
    df2 = jnp.zeros(core_shapes[1], jnp.float32)
    df2 = df2.at[:, :, j2, :].add(da2.transpose(1, 2, 0, 3))  # (r1,m2,K,r2)
    df3 = jnp.zeros(core_shapes[2], jnp.float32)
    df3 = df3.at[:, :, j3, 0].add(da3.transpose(1, 2, 0))  # (r2, m3, K)
    return None, (df1, df2, df3)


ttm_embedding.defvjp(_ttm_embedding_fwd, _ttm_embedding_bwd)


# ---------------------------------------------------------------------------
# Fused attention with reference backward
# ---------------------------------------------------------------------------


@jax.custom_vjp
def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, mask: jnp.ndarray):
    """Masked multi-head attention; Pallas forward, textbook backward.

    ``q``/``k``/``v``: (H, S, Dh); ``mask``: (S,) floats.
    """
    return fused_attention(q, k, v, mask)


def _attention_fwd(q, k, v, mask):
    return fused_attention(q, k, v, mask), (q, k, v, mask)


def _attention_bwd(res, do):
    q, k, v, mask = res
    # Recompute-style backward via the reference implementation (the
    # Pallas forward and the oracle agree to float tolerance; tested).
    _, vjp = jax.vjp(ref_kernels.naive_attention, q, k, v, mask)
    dq, dk, dv, _ = vjp(do)
    return dq, dk, dv, jnp.zeros_like(mask)


attention.defvjp(_attention_fwd, _attention_bwd)
